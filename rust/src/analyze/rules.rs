//! The srclint rules (R1–R5) over the token stream from [`super::lexer`].
//!
//! Per-file scanning lives here; the cross-file rule R5 (env-var
//! registry drift) only *extracts* references here — the README
//! comparison happens in [`super::report`], which sees every file.
//!
//! Rules (see README "Static analysis & concurrency verification"):
//! - **R1** no bare `.lock().unwrap()/expect()` / `.wait*(..).unwrap()`
//!   outside `util/sync.rs` and test code — use `util::sync::*_clean`.
//! - **R2** every `Ordering::` use must match `contract::ATOMIC_CONTRACT`.
//! - **R3** no `unwrap`/`expect`/`panic!`/user-input indexing in the
//!   serving hot path outside tests and `catch_unwind` bodies.
//! - **R4** no `Instant`/`SystemTime` in deterministic modules.
//! - **R5** `CVAPPROX_*` env vars ⊆ README registry (and vice versa).
//! - **SUP** a `// srclint: allow(Rn, reason)` comment must carry a
//!   well-formed rule id and a non-empty reason.

use super::contract;
use super::lexer::{tokenize, TokKind, Token};

/// One lint finding. `rule` is `"R1"`..`"R5"` or `"SUP"`.
#[derive(Clone, Debug)]
pub struct Finding {
    pub file: String,
    pub line: u32,
    pub rule: &'static str,
    pub message: String,
}

/// A parsed, well-formed suppression comment.
#[derive(Clone, Debug)]
pub struct Suppression {
    pub file: String,
    pub line: u32,
    pub rule: String,
    pub reason: String,
}

/// Per-file lint result; `env_refs` feeds the cross-file R5 check.
#[derive(Default)]
pub struct FileLint {
    pub findings: Vec<Finding>,
    pub suppressions: Vec<Suppression>,
    pub env_refs: Vec<(String, u32)>,
}

const WAIT_METHODS: &[&str] = &["wait", "wait_timeout", "wait_while", "wait_timeout_while"];

/// Lint one source file. `relpath` is repo-relative with `/` separators
/// (it selects which rules apply and is the key into the contract).
pub fn lint_source(relpath: &str, src: &str) -> FileLint {
    let toks = tokenize(src);
    let code: Vec<&Token> = toks.iter().filter(|t| t.kind != TokKind::Comment).collect();
    let test_regions = find_test_regions(&code);
    // Whole files under rust/tests/ are test context by definition.
    let is_test_file = relpath.starts_with("rust/tests/");
    let in_test = |line: u32| {
        is_test_file || test_regions.iter().any(|&(a, b)| a <= line && line <= b)
    };

    let mut out = FileLint::default();

    scan_suppressions(relpath, &toks, &mut out);
    if relpath != contract::SYNC_WRAPPER_FILE {
        scan_r1(relpath, &code, &in_test, &mut out.findings);
    }
    if relpath.starts_with("rust/src/") {
        scan_r2(relpath, &code, &in_test, &mut out.findings);
    }
    if contract::HOT_PATH_DIRS.iter().any(|d| relpath.starts_with(d)) {
        scan_r3(relpath, &code, &in_test, &mut out.findings);
    }
    if contract::DETERMINISTIC_MODULES.contains(&relpath) {
        scan_r4(relpath, &code, &mut out.findings);
    }
    // Env refs come from string literals only: comments mentioning
    // families like "CVAPPROX_QOS_*" are documentation, not reads. Test
    // regions are excluded too — fixture literals in tests are not
    // configuration surface (benches are real reads and stay in).
    for t in toks.iter().filter(|t| t.kind == TokKind::Str) {
        if in_test(t.line) {
            continue;
        }
        for v in vars_in(&t.text) {
            out.env_refs.push((v, t.line));
        }
    }
    out
}

/// Extract `CVAPPROX_*` variable names (with line numbers) from raw,
/// non-Rust text — shell scripts and workflow YAML.
pub fn extract_env_vars(text: &str) -> Vec<(String, u32)> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        for v in vars_in(line) {
            out.push((v, (i + 1) as u32));
        }
    }
    out
}

/// `CVAPPROX` not preceded by a word character, then `[A-Z0-9_]*`, with
/// trailing underscores trimmed; the bare prefix alone is skipped.
fn vars_in(text: &str) -> Vec<String> {
    let cs: Vec<char> = text.chars().collect();
    let needle: Vec<char> = "CVAPPROX".chars().collect();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i + needle.len() <= cs.len() {
        let word_before =
            i > 0 && (cs[i - 1].is_ascii_alphanumeric() || cs[i - 1] == '_');
        if !word_before && cs[i..i + needle.len()] == needle[..] {
            let mut j = i + needle.len();
            while j < cs.len() && (cs[j].is_ascii_uppercase() || cs[j].is_ascii_digit() || cs[j] == '_')
            {
                j += 1;
            }
            let name: String = cs[i..j].iter().collect();
            let name = name.trim_end_matches('_').to_string();
            if name != "CVAPPROX" {
                out.push(name);
            }
            i = j;
        } else {
            i += 1;
        }
    }
    out
}

/// Drop findings covered by a suppression on the same or the preceding
/// line; returns the surviving findings and how many were suppressed.
/// `SUP` findings are never suppressible — the escape hatch cannot hide
/// its own lint.
pub fn apply_suppressions(
    findings: Vec<Finding>,
    sups: &[Suppression],
) -> (Vec<Finding>, usize) {
    let mut suppressed = 0usize;
    let kept = findings
        .into_iter()
        .filter(|f| {
            let hit = f.rule != "SUP"
                && sups.iter().any(|s| {
                    s.file == f.file
                        && s.rule == f.rule
                        && (f.line == s.line || f.line == s.line + 1)
                });
            if hit {
                suppressed += 1;
            }
            !hit
        })
        .collect();
    (kept, suppressed)
}

// ---------------------------------------------------------------------
// test-region detection
// ---------------------------------------------------------------------

/// Line spans covered by `#[cfg(test)]` / `#[test]` items. Matches the
/// attribute token pattern, skips any further attributes, then scans to
/// the item's body `{` (tracking nesting) and records the span of its
/// matching `}`. Items ending in `;` contribute no span.
fn find_test_regions(code: &[&Token]) -> Vec<(u32, u32)> {
    let mut spans = Vec::new();
    let mut i = 0usize;
    while i + 1 < code.len() {
        if !(code[i].is_punct('#') && code[i + 1].is_punct('[')) {
            i += 1;
            continue;
        }
        let Some(close) = match_forward(code, i + 1, '[', ']') else { break };
        let is_test_attr = code[i + 2..close]
            .iter()
            .any(|t| t.is_ident("test"));
        let mut j = close + 1;
        if is_test_attr {
            // Skip any further attributes on the same item.
            while j + 1 < code.len() && code[j].is_punct('#') && code[j + 1].is_punct('[') {
                match match_forward(code, j + 1, '[', ']') {
                    Some(c) => j = c + 1,
                    None => break,
                }
            }
            // Find the item body's `{` (or a terminating `;`), tracking
            // paren/bracket depth so e.g. generic bounds don't confuse us.
            let mut depth = 0i32;
            let mut body = None;
            while j < code.len() {
                let t = code[j];
                if t.is_punct('(') || t.is_punct('[') {
                    depth += 1;
                } else if t.is_punct(')') || t.is_punct(']') {
                    depth -= 1;
                } else if depth == 0 && t.is_punct('{') {
                    body = Some(j);
                    break;
                } else if depth == 0 && t.is_punct(';') {
                    break;
                }
                j += 1;
            }
            if let Some(b) = body {
                if let Some(end) = match_forward(code, b, '{', '}') {
                    spans.push((code[b].line, code[end].line));
                    i = end + 1;
                    continue;
                }
            }
        }
        i = close + 1;
    }
    spans
}

/// Index of the token closing the delimiter opened at `open_idx`.
fn match_forward(code: &[&Token], open_idx: usize, open: char, close: char) -> Option<usize> {
    let mut depth = 0i32;
    for (k, t) in code.iter().enumerate().skip(open_idx) {
        if t.is_punct(open) {
            depth += 1;
        } else if t.is_punct(close) {
            depth -= 1;
            if depth == 0 {
                return Some(k);
            }
        }
    }
    None
}

// ---------------------------------------------------------------------
// R1: bare lock()/wait*() + unwrap/expect
// ---------------------------------------------------------------------

fn scan_r1(
    relpath: &str,
    code: &[&Token],
    in_test: &dyn Fn(u32) -> bool,
    findings: &mut Vec<Finding>,
) {
    let mut i = 0usize;
    while i + 2 < code.len() {
        if !code[i].is_punct('.') || code[i + 1].kind != TokKind::Ident {
            i += 1;
            continue;
        }
        let m = code[i + 1].text.as_str();
        let is_lock = m == "lock";
        let is_wait = WAIT_METHODS.contains(&m);
        if !(is_lock || is_wait) || !code[i + 2].is_punct('(') {
            i += 1;
            continue;
        }
        let Some(close) = match_forward(code, i + 2, '(', ')') else { break };
        // `Mutex::lock` takes no args; `Condvar::wait*` always takes the
        // guard. This split keeps unrelated `wait()` methods (e.g. the
        // retry client's `Pending::wait()`) out of scope.
        let arity_ok = if is_lock { close == i + 3 } else { close > i + 3 };
        let j = close + 1;
        if arity_ok
            && j + 2 < code.len()
            && code[j].is_punct('.')
            && (code[j + 1].is_ident("unwrap") || code[j + 1].is_ident("expect"))
            && code[j + 2].is_punct('(')
        {
            let line = code[j + 1].line;
            if !in_test(line) {
                findings.push(Finding {
                    file: relpath.to_string(),
                    line,
                    rule: "R1",
                    message: format!(
                        "bare `.{m}(..).{}()` — use util::sync::{} so a \
                         poisoned lock cannot cascade",
                        code[j + 1].text,
                        if is_lock { "lock_clean" } else { "wait_clean/wait_timeout_clean" },
                    ),
                });
            }
        }
        i = j;
    }
}

// ---------------------------------------------------------------------
// R2: atomics-ordering contract
// ---------------------------------------------------------------------

fn scan_r2(
    relpath: &str,
    code: &[&Token],
    in_test: &dyn Fn(u32) -> bool,
    findings: &mut Vec<Finding>,
) {
    for i in 0..code.len() {
        if !(code[i].is_ident("Ordering")
            && i + 3 < code.len()
            && code[i + 1].is_punct(':')
            && code[i + 2].is_punct(':')
            && code[i + 3].kind == TokKind::Ident
            && contract::ATOMIC_ORDERINGS.contains(&code[i + 3].text.as_str()))
        {
            continue;
        }
        let variant = code[i + 3].text.as_str();
        let line = code[i].line;
        if in_test(line) {
            continue;
        }
        let mut fail = |msg: String| {
            findings.push(Finding {
                file: relpath.to_string(),
                line,
                rule: "R2",
                message: msg,
            })
        };
        // Walk back to the `(` of the enclosing call, over balanced parens.
        let mut depth = 0i32;
        let mut open = None;
        for j in (0..i).rev() {
            let t = code[j];
            if t.is_punct(')') {
                depth += 1;
            } else if t.is_punct('(') {
                if depth == 0 {
                    open = Some(j);
                    break;
                }
                depth -= 1;
            } else if depth == 0 && (t.is_punct(';') || t.is_punct('{') || t.is_punct('}')) {
                break;
            }
        }
        let Some(open) = open else {
            fail(format!("`Ordering::{variant}` outside any call expression"));
            continue;
        };
        if open == 0 || code[open - 1].kind != TokKind::Ident {
            fail(format!("`Ordering::{variant}` not anchored to a method call"));
            continue;
        }
        let method = code[open - 1].text.as_str();
        if !contract::ATOMIC_METHODS.contains(&method) {
            fail(format!(
                "`Ordering::{variant}` passed to `{method}`, which is not a \
                 recognized atomic operation"
            ));
            continue;
        }
        // Receiver: `recv.method(` or `recv[..].method(`.
        let recv = if open >= 3 && code[open - 2].is_punct('.') {
            let mut r = open - 3;
            if code[r].is_punct(']') {
                // e.g. `self.lat_us[(j % cap) as usize].load(..)`
                let mut d = 0i32;
                let mut found = None;
                for k in (0..=r).rev() {
                    if code[k].is_punct(']') {
                        d += 1;
                    } else if code[k].is_punct('[') {
                        d -= 1;
                        if d == 0 {
                            found = Some(k);
                            break;
                        }
                    }
                }
                match found {
                    Some(k) if k >= 1 => r = k - 1,
                    _ => {
                        fail(format!("cannot resolve indexed receiver of `{method}`"));
                        continue;
                    }
                }
            }
            if code[r].kind == TokKind::Ident {
                code[r].text.clone()
            } else {
                fail(format!("cannot resolve receiver of `{method}`"));
                continue;
            }
        } else {
            fail(format!("cannot resolve receiver of `{method}`"));
            continue;
        };
        match contract::lookup(relpath, &recv) {
            None => fail(format!(
                "atomic `{recv}` has no row in analyze::contract::ATOMIC_CONTRACT \
                 — add one with a rationale"
            )),
            Some(rule) if !rule.allowed.contains(&variant) => fail(format!(
                "`{recv}.{method}(Ordering::{variant})` violates the contract \
                 (allowed: {}) — {}",
                rule.allowed.join("/"),
                rule.rationale
            )),
            Some(_) => {}
        }
    }
}

// ---------------------------------------------------------------------
// R3: panics in the serving hot path
// ---------------------------------------------------------------------

fn scan_r3(
    relpath: &str,
    code: &[&Token],
    in_test: &dyn Fn(u32) -> bool,
    findings: &mut Vec<Finding>,
) {
    // Lines lexically inside a `catch_unwind(..)` argument are exempt:
    // that is the one place a panic is contained by design.
    let mut caught: Vec<(u32, u32)> = Vec::new();
    for i in 0..code.len() {
        if code[i].is_ident("catch_unwind") && i + 1 < code.len() && code[i + 1].is_punct('(') {
            if let Some(close) = match_forward(code, i + 1, '(', ')') {
                caught.push((code[i].line, code[close].line));
            }
        }
    }
    let exempt =
        |line: u32| in_test(line) || caught.iter().any(|&(a, b)| a <= line && line <= b);

    for i in 0..code.len() {
        let t = code[i];
        if t.is_punct('.')
            && i + 2 < code.len()
            && (code[i + 1].is_ident("unwrap") || code[i + 1].is_ident("expect"))
            && code[i + 2].is_punct('(')
            && !exempt(code[i + 1].line)
        {
            findings.push(Finding {
                file: relpath.to_string(),
                line: code[i + 1].line,
                rule: "R3",
                message: format!(
                    "`.{}()` in the serving hot path — return a typed error \
                     instead of panicking a worker",
                    code[i + 1].text
                ),
            });
        }
        if t.is_ident("panic")
            && i + 1 < code.len()
            && code[i + 1].is_punct('!')
            && !exempt(t.line)
        {
            findings.push(Finding {
                file: relpath.to_string(),
                line: t.line,
                rule: "R3",
                message: "`panic!` in the serving hot path — workers must fail \
                          through typed ReplyError, not unwinding"
                    .to_string(),
            });
        }
        if t.kind == TokKind::Ident
            && contract::USER_INPUT_RECEIVERS.contains(&t.text.as_str())
            && i + 1 < code.len()
            && code[i + 1].is_punct('[')
            && !exempt(t.line)
        {
            findings.push(Finding {
                file: relpath.to_string(),
                line: t.line,
                rule: "R3",
                message: format!(
                    "direct `{}[..]` indexing on request-derived data — a \
                     malformed request must become BadInput, not a panic",
                    t.text
                ),
            });
        }
    }
}

// ---------------------------------------------------------------------
// R4: wall-clock reads in deterministic modules
// ---------------------------------------------------------------------

fn scan_r4(relpath: &str, code: &[&Token], findings: &mut Vec<Finding>) {
    for t in code {
        if t.is_ident("Instant") || t.is_ident("SystemTime") {
            findings.push(Finding {
                file: relpath.to_string(),
                line: t.line,
                rule: "R4",
                message: format!(
                    "`{}` in a deterministic module — seeded schedules and \
                     goldens must be replay-exact functions of the seed",
                    t.text
                ),
            });
        }
    }
}

// ---------------------------------------------------------------------
// suppression comments
// ---------------------------------------------------------------------

fn scan_suppressions(relpath: &str, toks: &[Token], out: &mut FileLint) {
    for t in toks.iter().filter(|t| t.kind == TokKind::Comment) {
        // Only comments that *start* with `srclint:` (after the comment
        // sigils) are suppression candidates — docs may mention the syntax
        // in backticks without becoming suppressions themselves.
        let body = t
            .text
            .trim_start_matches(|c| matches!(c, '/' | '*' | '!' | ' ' | '\t'));
        let Some(rest) = body.strip_prefix("srclint:") else { continue };
        let rest = rest.trim();
        match parse_allow(rest) {
            Some((rule, reason)) => out.suppressions.push(Suppression {
                file: relpath.to_string(),
                line: t.line,
                rule,
                reason,
            }),
            None => out.findings.push(Finding {
                file: relpath.to_string(),
                line: t.line,
                rule: "SUP",
                message: "malformed suppression — expected \
                          `// srclint: allow(Rn, reason)` with a non-empty reason"
                    .to_string(),
            }),
        }
    }
}

/// Parse `allow(Rn, reason)`; the reason must be non-empty.
fn parse_allow(s: &str) -> Option<(String, String)> {
    let body = s.strip_prefix("allow(")?;
    let close = body.rfind(')')?;
    let inner = &body[..close];
    let (rule, reason) = inner.split_once(',')?;
    let rule = rule.trim();
    let reason = reason.trim();
    let known = matches!(rule, "R1" | "R2" | "R3" | "R4" | "R5");
    if known && !reason.is_empty() {
        Some((rule.to_string(), reason.to_string()))
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_of(relpath: &str, src: &str) -> Vec<&'static str> {
        let lint = lint_source(relpath, src);
        let (kept, _) = apply_suppressions(lint.findings, &lint.suppressions);
        kept.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn r1_fires_on_bare_lock_unwrap_only_outside_tests() {
        let bad = "fn f(m: &Mutex<u32>) -> u32 { *m.lock().unwrap() }";
        assert_eq!(rules_of("rust/src/x.rs", bad), ["R1"]);
        // Same code inside #[cfg(test)] or util/sync.rs is fine.
        let test_wrapped = format!("#[cfg(test)]\nmod tests {{ {bad} }}");
        assert!(rules_of("rust/src/x.rs", &test_wrapped).is_empty());
        assert!(rules_of("rust/src/util/sync.rs", bad).is_empty());
        // lock_clean passes; Pending-style `wait()` (no guard arg) passes.
        assert!(rules_of("rust/src/x.rs", "fn f() { lock_clean(&m); p.wait().unwrap(); }")
            .is_empty());
        // Condvar wait with a guard arg fails.
        assert_eq!(
            rules_of("rust/src/x.rs", "fn f() { let g = cv.wait(g).unwrap(); }"),
            ["R1"]
        );
    }

    #[test]
    fn r2_checks_the_contract() {
        // Allowed by contract: inject.rs seq is Relaxed.
        let ok = "fn f(&self) { self.seq.load(Ordering::Relaxed); }";
        assert!(rules_of("rust/src/fault/inject.rs", ok).is_empty());
        // Disallowed ordering on a known atomic.
        let bad = "fn f(&self) { self.seq.load(Ordering::SeqCst); }";
        assert_eq!(rules_of("rust/src/fault/inject.rs", bad), ["R2"]);
        // Unknown atomic entirely.
        let unknown = "fn f(&self) { self.mystery.load(Ordering::Relaxed); }";
        assert_eq!(rules_of("rust/src/util/rng.rs", unknown), ["R2"]);
        // cmp::Ordering variants never match.
        assert!(rules_of("rust/src/x.rs", "fn f() { if o == Ordering::Less {} }").is_empty());
    }

    #[test]
    fn r2_resolves_indexed_receivers_and_fetch_update() {
        let src = "impl T { fn f(&self) { \
                   self.lat_us[(j % cap) as usize].load(Ordering::Acquire); \
                   self.inflight.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| Some(v)); } }";
        assert!(rules_of("rust/src/qos/telemetry.rs", src).is_empty());
    }

    #[test]
    fn r3_hot_path_panics() {
        let bad = "fn f(x: Option<u32>) { x.unwrap(); panic!(\"no\"); let v = image[i]; }";
        assert_eq!(
            rules_of("rust/src/coordinator/x.rs", bad),
            ["R3", "R3", "R3"]
        );
        // Outside hot path: no findings.
        assert!(rules_of("rust/src/nn/x.rs", bad).is_empty());
        // Inside catch_unwind: exempt.
        let caught = "fn f() { let r = catch_unwind(AssertUnwindSafe(|| x.unwrap())); }";
        assert!(rules_of("rust/src/coordinator/x.rs", caught).is_empty());
        // unwrap_or_else is not unwrap.
        assert!(rules_of(
            "rust/src/coordinator/x.rs",
            "fn f() { g.unwrap_or_else(|e| e.into_inner()); }"
        )
        .is_empty());
    }

    #[test]
    fn r4_wall_clock() {
        let bad = "fn f() { let t = Instant::now(); }";
        assert_eq!(rules_of("rust/src/util/rng.rs", bad), ["R4"]);
        assert!(rules_of("rust/src/util/other.rs", bad).is_empty());
    }

    #[test]
    fn suppressions_round_trip() {
        let src = "fn f(m: &Mutex<u32>) {\n\
                   // srclint: allow(R1, poison is impossible here by construction)\n\
                   m.lock().unwrap();\n}";
        let lint = lint_source("rust/src/x.rs", src);
        assert_eq!(lint.suppressions.len(), 1);
        let (kept, suppressed) = apply_suppressions(lint.findings, &lint.suppressions);
        assert!(kept.is_empty());
        assert_eq!(suppressed, 1);
        // Wrong rule id in the comment -> finding survives.
        let src2 = src.replace("allow(R1,", "allow(R2,");
        let lint2 = lint_source("rust/src/x.rs", &src2);
        let (kept2, _) = apply_suppressions(lint2.findings, &lint2.suppressions);
        assert_eq!(kept2.len(), 1);
    }

    #[test]
    fn malformed_suppression_is_its_own_finding() {
        for bad in [
            "// srclint: allow(R1)",
            "// srclint: allow(R1, )",
            "// srclint: allow(R9, reason)",
            "// srclint: allowed",
        ] {
            assert_eq!(rules_of("rust/src/x.rs", bad), ["SUP"], "{bad}");
        }
    }

    #[test]
    fn env_vars_extracted_from_strings_not_comments() {
        let src = "// mentions CVAPPROX_FAKE_IN_COMMENT\n\
                   fn f() { std::env::var(\"CVAPPROX_THREADS\"); }";
        let lint = lint_source("rust/src/x.rs", src);
        let names: Vec<&str> = lint.env_refs.iter().map(|(v, _)| v.as_str()).collect();
        assert_eq!(names, ["CVAPPROX_THREADS"]);
        // Raw-text extraction for shell scripts, ${VAR:-} form included.
        let sh = "x=\"${CVAPPROX_SKIP_LINT:-}\"\n: \"${CVAPPROX_QOS_TICK_MS}\"";
        let vars = extract_env_vars(sh);
        assert_eq!(vars[0].0, "CVAPPROX_SKIP_LINT");
        assert_eq!(vars[1], ("CVAPPROX_QOS_TICK_MS".to_string(), 2));
    }

    #[test]
    fn test_region_detection_spans_nested_braces() {
        let src = "fn live() { m.lock().unwrap(); }\n\
                   #[cfg(test)]\nmod tests {\n  fn inner() { if x { m.lock().unwrap(); } }\n}\n\
                   fn live2() { m.lock().unwrap(); }";
        let rules = rules_of("rust/src/x.rs", src);
        assert_eq!(rules, ["R1", "R1"]); // only the two live fns
    }
}
