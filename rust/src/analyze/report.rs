//! Tree walking, the cross-file R5 registry check, and the
//! `LINT_report.json` artifact.

use std::collections::{BTreeMap, BTreeSet};
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::contract::{ENV_REGISTRY_BEGIN, ENV_REGISTRY_END};
use super::rules::{self, Finding, Suppression};
use crate::util::json::Json;

/// The complete result of linting a tree.
pub struct LintReport {
    pub root: PathBuf,
    pub files_scanned: usize,
    /// Findings that survived suppression, sorted by (file, line, rule).
    pub findings: Vec<Finding>,
    /// How many findings were silenced by `srclint: allow` comments.
    pub suppressed: usize,
    /// Every suppression comment in the tree (whether or not it fired).
    pub suppressions: Vec<Suppression>,
}

impl LintReport {
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .field("tool", "srclint")
            .field("root", self.root.display().to_string())
            .field("files_scanned", self.files_scanned)
            .field("suppressed", self.suppressed)
            .field(
                "findings",
                Json::Arr(
                    self.findings
                        .iter()
                        .map(|f| {
                            Json::obj()
                                .field("file", f.file.as_str())
                                .field("line", f.line)
                                .field("rule", f.rule)
                                .field("message", f.message.as_str())
                        })
                        .collect(),
                ),
            )
            .field(
                "suppressions",
                Json::Arr(
                    self.suppressions
                        .iter()
                        .map(|s| {
                            Json::obj()
                                .field("file", s.file.as_str())
                                .field("line", s.line)
                                .field("rule", s.rule.as_str())
                                .field("reason", s.reason.as_str())
                        })
                        .collect(),
                ),
            )
    }

    /// Human-readable finding lines (`file:line [Rn] message`).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&format!("{}:{} [{}] {}\n", f.file, f.line, f.rule, f.message));
        }
        out.push_str(&format!(
            "srclint: {} finding(s), {} suppressed, {} file(s) scanned\n",
            self.findings.len(),
            self.suppressed,
            self.files_scanned
        ));
        out
    }
}

/// Lint the tree rooted at `root` (the repo root: the directory holding
/// `rust/`, `benches/`, `scripts/`, `README.md`).
pub fn run_lint(root: &Path) -> Result<LintReport> {
    if !root.join("rust/src").is_dir() {
        bail!("{} does not look like a repo root (no rust/src)", root.display());
    }
    let mut findings: Vec<Finding> = Vec::new();
    let mut suppressions: Vec<Suppression> = Vec::new();
    // var -> first (file, line) that reads it, for R5 anchoring.
    let mut code_vars: BTreeMap<String, (String, u32)> = BTreeMap::new();
    let mut files_scanned = 0usize;

    let mut rs_files = Vec::new();
    collect_rs(&root.join("rust/src"), &mut rs_files)?;
    collect_rs(&root.join("rust/tests"), &mut rs_files)?;
    collect_rs(&root.join("benches"), &mut rs_files)?;
    rs_files.sort();

    for path in &rs_files {
        let rel = rel_unix(root, path);
        let src = fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let lint = rules::lint_source(&rel, &src);
        findings.extend(lint.findings);
        suppressions.extend(lint.suppressions);
        for (var, line) in lint.env_refs {
            code_vars.entry(var).or_insert((rel.clone(), line));
        }
        files_scanned += 1;
    }

    // Shell scripts and workflow YAML read env vars too; they are plain
    // text, not Rust, so only the R5 extractor runs on them.
    let mut raw_files = Vec::new();
    collect_ext(&root.join("scripts"), "sh", &mut raw_files)?;
    collect_ext(&root.join(".github/workflows"), "yml", &mut raw_files)?;
    raw_files.sort();
    for path in &raw_files {
        let rel = rel_unix(root, path);
        let text = fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        for (var, line) in rules::extract_env_vars(&text) {
            code_vars.entry(var).or_insert((rel.clone(), line));
        }
        files_scanned += 1;
    }

    findings.extend(check_env_registry(root, &code_vars)?);

    let (mut kept, suppressed) = rules::apply_suppressions(findings, &suppressions);
    kept.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    Ok(LintReport {
        root: root.to_path_buf(),
        files_scanned,
        findings: kept,
        suppressed,
        suppressions,
    })
}

/// R5: the README registry between the srclint markers must list exactly
/// the `CVAPPROX_*` vars the code reads — drift in either direction is a
/// finding.
fn check_env_registry(
    root: &Path,
    code_vars: &BTreeMap<String, (String, u32)>,
) -> Result<Vec<Finding>> {
    let mut out = Vec::new();
    let readme_path = root.join("README.md");
    let readme = fs::read_to_string(&readme_path)
        .with_context(|| format!("reading {}", readme_path.display()))?;
    let begin = readme.find(ENV_REGISTRY_BEGIN);
    let end = readme.find(ENV_REGISTRY_END);
    let (Some(b), Some(e)) = (begin, end) else {
        out.push(Finding {
            file: "README.md".into(),
            line: 1,
            rule: "R5",
            message: format!(
                "env-var registry markers `{ENV_REGISTRY_BEGIN}` / \
                 `{ENV_REGISTRY_END}` not found in README.md"
            ),
        });
        return Ok(out);
    };
    if e < b {
        bail!("README env-registry end marker precedes begin marker");
    }
    let base_line = readme[..b].lines().count() as u32;
    let mut registry: BTreeMap<String, u32> = BTreeMap::new();
    for (var, line) in rules::extract_env_vars(&readme[b..e]) {
        registry.entry(var).or_insert(base_line + line - 1);
    }
    let reg_set: BTreeSet<&String> = registry.keys().collect();
    for (var, (file, line)) in code_vars {
        if !reg_set.contains(var) {
            out.push(Finding {
                file: file.clone(),
                line: *line,
                rule: "R5",
                message: format!(
                    "env var `{var}` is read here but missing from the README \
                     env-var registry"
                ),
            });
        }
    }
    for (var, line) in &registry {
        if !code_vars.contains_key(var) {
            out.push(Finding {
                file: "README.md".into(),
                line: *line,
                rule: "R5",
                message: format!(
                    "registry lists `{var}` but nothing in the tree reads it \
                     — stale entry"
                ),
            });
        }
    }
    Ok(out)
}

/// Recursively collect `.rs` files; missing directories are fine (fixture
/// trees may omit `benches/`).
fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    collect_ext(dir, "rs", out)
}

fn collect_ext(dir: &Path, ext: &str, out: &mut Vec<PathBuf>) -> Result<()> {
    if !dir.is_dir() {
        return Ok(());
    }
    let mut entries: Vec<_> = fs::read_dir(dir)
        .with_context(|| format!("listing {}", dir.display()))?
        .collect::<std::io::Result<Vec<_>>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            collect_ext(&p, ext, out)?;
        } else if p.extension().and_then(|s| s.to_str()) == Some(ext) {
            out.push(p);
        }
    }
    Ok(())
}

/// Repo-relative path with `/` separators regardless of platform.
fn rel_unix(root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    rel.components()
        .map(|c| c.as_os_str().to_string_lossy())
        .collect::<Vec<_>>()
        .join("/")
}
