//! A small hand-rolled Rust tokenizer for the source linter (`srclint`).
//!
//! Same hermetic philosophy as `util::json`: no `syn`/`proc-macro2`
//! offline, so the rules run on a loose token stream instead of a real
//! AST. The lexer only needs to be exact about the things that would make
//! a *lint* wrong — comments vs code, string contents vs code, lifetimes
//! vs char literals, and line numbers — not about full Rust grammar.
//! Numeric literals, for example, are scanned loosely (enough to not eat a
//! `..` range or a method call on a literal), because no rule looks inside
//! them.

/// Token class. `Comment` and `Str` keep their text (suppression comments
/// and the `CVAPPROX_*` env-var scan read it); everything else keeps text
/// for pattern matching on idents/punctuation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`lock`, `Ordering`, `fn`, ...).
    Ident,
    /// Single punctuation character (`.`/`(`/`::` arrives as two `:`).
    Punct,
    /// Numeric literal, scanned loosely (`0x9E37_79B9`, `1.0e-3`, `2_u64`).
    Num,
    /// String literal: plain, raw (`r#"..."#`), byte, or C; text includes
    /// the quotes.
    Str,
    /// Char or byte-char literal (`'a'`, `b'\n'`, `'\u{1F600}'`).
    Char,
    /// Lifetime (`'a`, `'static`).
    Lifetime,
    /// Line or block comment, text included (suppressions live here).
    Comment,
}

/// One token with its 1-based source line (the line it *starts* on).
#[derive(Clone, Debug)]
pub struct Token {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

impl Token {
    pub fn is(&self, kind: TokKind, text: &str) -> bool {
        self.kind == kind && self.text == text
    }

    pub fn is_punct(&self, c: char) -> bool {
        self.kind == TokKind::Punct && self.text.len() == c.len_utf8() && self.text.starts_with(c)
    }

    pub fn is_ident(&self, name: &str) -> bool {
        self.is(TokKind::Ident, name)
    }
}

/// Tokenize `src`. Never fails: unrecognized bytes become single-char
/// `Punct` tokens, and unterminated literals/comments run to end of file —
/// a linter must degrade gracefully on code it half-understands, not
/// panic.
pub fn tokenize(src: &str) -> Vec<Token> {
    let cs: Vec<char> = src.chars().collect();
    let n = cs.len();
    let mut out = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < n {
        let c = cs[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Line comment (also doc comments `///` / `//!`).
        if c == '/' && i + 1 < n && cs[i + 1] == '/' {
            let start = i;
            while i < n && cs[i] != '\n' {
                i += 1;
            }
            out.push(Token {
                kind: TokKind::Comment,
                text: cs[start..i].iter().collect(),
                line,
            });
            continue;
        }
        // Block comment, nested per Rust rules.
        if c == '/' && i + 1 < n && cs[i + 1] == '*' {
            let start = i;
            let start_line = line;
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if cs[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if cs[i] == '/' && i + 1 < n && cs[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if cs[i] == '*' && i + 1 < n && cs[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            out.push(Token {
                kind: TokKind::Comment,
                text: cs[start..i].iter().collect(),
                line: start_line,
            });
            continue;
        }
        // Raw / byte string prefixes: r"..", r#".."#, b"..", br#".."#.
        if let Some((body_at, hashes)) = raw_string_start(&cs, i) {
            let start = i;
            let start_line = line;
            i = body_at; // first char after the opening quote
            loop {
                if i >= n {
                    break;
                }
                if cs[i] == '\n' {
                    line += 1;
                    i += 1;
                    continue;
                }
                if cs[i] == '"' && i + hashes < n && cs[i + 1..i + 1 + hashes].iter().all(|&h| h == '#')
                {
                    i += 1 + hashes;
                    break;
                }
                i += 1;
            }
            out.push(Token {
                kind: TokKind::Str,
                text: cs[start..i.min(n)].iter().collect(),
                line: start_line,
            });
            continue;
        }
        // Plain / byte string.
        if c == '"' || (c == 'b' && i + 1 < n && cs[i + 1] == '"') {
            let start = i;
            let start_line = line;
            i += if c == 'b' { 2 } else { 1 };
            while i < n {
                if cs[i] == '\\' {
                    i += 2;
                } else if cs[i] == '"' {
                    i += 1;
                    break;
                } else {
                    if cs[i] == '\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            out.push(Token {
                kind: TokKind::Str,
                text: cs[start..i.min(n)].iter().collect(),
                line: start_line,
            });
            continue;
        }
        // Byte-char literal b'x'.
        if c == 'b' && i + 1 < n && cs[i + 1] == '\'' {
            let start = i;
            i += 2;
            i = scan_char_body(&cs, i);
            out.push(Token {
                kind: TokKind::Char,
                text: cs[start..i.min(n)].iter().collect(),
                line,
            });
            continue;
        }
        // Char literal vs lifetime.
        if c == '\'' {
            // '\...' is always a char; 'X' (any single char, then a quote)
            // is a char; otherwise an ident-ish tail is a lifetime.
            if i + 1 < n && cs[i + 1] == '\\' {
                let start = i;
                i += 1;
                i = scan_char_body(&cs, i);
                out.push(Token {
                    kind: TokKind::Char,
                    text: cs[start..i.min(n)].iter().collect(),
                    line,
                });
                continue;
            }
            if i + 2 < n && cs[i + 2] == '\'' && cs[i + 1] != '\'' {
                let start = i;
                i += 3;
                out.push(Token {
                    kind: TokKind::Char,
                    text: cs[start..i].iter().collect(),
                    line,
                });
                continue;
            }
            if i + 1 < n && (cs[i + 1].is_alphabetic() || cs[i + 1] == '_') {
                let start = i;
                i += 1;
                while i < n && (cs[i].is_alphanumeric() || cs[i] == '_') {
                    i += 1;
                }
                out.push(Token {
                    kind: TokKind::Lifetime,
                    text: cs[start..i].iter().collect(),
                    line,
                });
                continue;
            }
            out.push(Token { kind: TokKind::Punct, text: "'".into(), line });
            i += 1;
            continue;
        }
        // Number (loose: hex/oct/bin, underscores, suffixes, exponents;
        // never consumes `..` or a method-call dot).
        if c.is_ascii_digit() {
            let start = i;
            let radix_prefixed = c == '0'
                && i + 1 < n
                && matches!(cs[i + 1], 'x' | 'X' | 'b' | 'B' | 'o' | 'O');
            i += 1;
            while i < n {
                let ch = cs[i];
                if ch.is_ascii_alphanumeric() || ch == '_' {
                    i += 1;
                } else if ch == '.'
                    && i + 1 < n
                    && cs[i + 1].is_ascii_digit()
                    && !radix_prefixed
                {
                    i += 1;
                } else if (ch == '+' || ch == '-')
                    && !radix_prefixed
                    && matches!(cs[i - 1], 'e' | 'E')
                {
                    i += 1;
                } else {
                    break;
                }
            }
            out.push(Token {
                kind: TokKind::Num,
                text: cs[start..i].iter().collect(),
                line,
            });
            continue;
        }
        // Identifier / keyword.
        if c.is_alphabetic() || c == '_' {
            let start = i;
            i += 1;
            while i < n && (cs[i].is_alphanumeric() || cs[i] == '_') {
                i += 1;
            }
            out.push(Token {
                kind: TokKind::Ident,
                text: cs[start..i].iter().collect(),
                line,
            });
            continue;
        }
        out.push(Token { kind: TokKind::Punct, text: c.to_string(), line });
        i += 1;
    }
    out
}

/// `Some((index_after_opening_quote, hash_count))` when `cs[i..]` starts a
/// raw (possibly byte) string literal.
fn raw_string_start(cs: &[char], i: usize) -> Option<(usize, usize)> {
    let n = cs.len();
    let mut j = i;
    if j < n && cs[j] == 'b' {
        j += 1;
    }
    if j >= n || cs[j] != 'r' {
        return None;
    }
    j += 1;
    let mut hashes = 0usize;
    while j < n && cs[j] == '#' {
        hashes += 1;
        j += 1;
    }
    if j < n && cs[j] == '"' {
        Some((j + 1, hashes))
    } else {
        None
    }
}

/// Scan a char-literal body starting at the char after the opening quote;
/// returns the index after the closing quote (handles `\'`, `\u{..}`).
fn scan_char_body(cs: &[char], mut i: usize) -> usize {
    let n = cs.len();
    while i < n {
        if cs[i] == '\\' {
            i += 2;
        } else if cs[i] == '\'' {
            return i + 1;
        } else {
            i += 1;
        }
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        tokenize(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn basic_stream() {
        let ts = kinds("let x = m.lock().unwrap();");
        let idents: Vec<&str> = ts
            .iter()
            .filter(|(k, _)| *k == TokKind::Ident)
            .map(|(_, s)| s.as_str())
            .collect();
        assert_eq!(idents, ["let", "x", "m", "lock", "unwrap"]);
    }

    #[test]
    fn comments_and_strings_do_not_leak_code() {
        let ts = kinds("// m.lock().unwrap()\nlet s = \"m.lock().unwrap()\";");
        let idents: Vec<&str> = ts
            .iter()
            .filter(|(k, _)| *k == TokKind::Ident)
            .map(|(_, s)| s.as_str())
            .collect();
        assert_eq!(idents, ["let", "s"]);
        assert!(ts.iter().any(|(k, s)| *k == TokKind::Comment && s.contains("unwrap")));
        assert!(ts.iter().any(|(k, s)| *k == TokKind::Str && s.contains("unwrap")));
    }

    #[test]
    fn nested_block_comment_and_raw_string() {
        let ts = kinds("/* a /* b */ c */ fn x() { r#\"q\"uo\"# }");
        assert_eq!(ts[0].0, TokKind::Comment);
        assert!(ts[0].1.ends_with("c */"));
        assert!(ts.iter().any(|(k, s)| *k == TokKind::Str && s.contains("q\"uo")));
    }

    #[test]
    fn lifetimes_vs_chars() {
        let ts = kinds("fn f<'a>(x: &'a str) -> char { 'x' } // plus '\\n' and b'z'");
        let lifes: Vec<&str> = ts
            .iter()
            .filter(|(k, _)| *k == TokKind::Lifetime)
            .map(|(_, s)| s.as_str())
            .collect();
        assert_eq!(lifes, ["'a", "'a"]);
        assert!(ts.iter().any(|(k, s)| *k == TokKind::Char && s == "'x'"));
        let ts2 = kinds("let c = '\\u{1F600}'; let b = b'q'; let s = 'static_oops");
        assert!(ts2.iter().any(|(k, s)| *k == TokKind::Char && s.contains("1F600")));
        assert!(ts2.iter().any(|(k, s)| *k == TokKind::Char && s == "b'q'"));
        assert!(ts2.iter().any(|(k, s)| *k == TokKind::Lifetime && s == "'static_oops"));
    }

    #[test]
    fn numbers_stay_loose_but_bounded() {
        // Ranges and method calls on literals must not be eaten.
        let ts = kinds("for i in 0..n { let x = 1.0e-3 + 0x9E37_79B9; let y = 7.max(2); }");
        let nums: Vec<&str> = ts
            .iter()
            .filter(|(k, _)| *k == TokKind::Num)
            .map(|(_, s)| s.as_str())
            .collect();
        assert_eq!(nums, ["0", "1.0e-3", "0x9E37_79B9", "7", "2"]);
        assert!(ts.iter().any(|(k, s)| *k == TokKind::Ident && s == "max"));
    }

    #[test]
    fn line_numbers_track_every_literal_form() {
        let src = "a\n\"two\nlines\"\n/* c\nc */\nfinal";
        let ts = tokenize(src);
        let find = |txt: &str| ts.iter().find(|t| t.text.contains(txt)).unwrap().line;
        assert_eq!(find("a"), 1);
        assert_eq!(find("two"), 2);
        assert_eq!(find("c */"), 4);
        assert_eq!(find("final"), 6);
    }

    #[test]
    fn unterminated_inputs_do_not_panic() {
        for src in ["\"abc", "/* abc", "r#\"abc", "'", "b'x", "0x"] {
            let _ = tokenize(src);
        }
    }
}
