//! Automated multiplier/assignment co-design search (ROADMAP item 4).
//!
//! A seeded, deterministic NSGA-II-style Pareto search over per-column
//! partial-product drop masks **jointly** with per-layer assignment. The
//! genome ([`genome::Genome`]) encodes, per MAC layer, which structural
//! family the drop mask carves out of the 8×8 Dadda array (row
//! perforation, column truncation, recursive sub-array), how many
//! positions it drops, the error polarity, whether the layer runs a
//! mirrored Neg/Pos pairing, and whether the CV epilogue is on. Candidates
//! are validated against the `bitmodel`/`dadda` structural models before
//! they are ever executed, scored on (estimated accuracy loss, MAC-
//! weighted normalized power) via the standard CV-epilogue evaluation
//! path, and gated on i32 K-headroom feasibility ([`evaluate`]).
//!
//! The whole run is reproducible from one seed: every random draw comes
//! from a single [`Rng`] stream on the main thread, fitness evaluation
//! parallelizes over [`crate::util::threadpool`] with order-preserving
//! results and per-candidate memoization keyed by the FNV-1a genome hash,
//! and every sort breaks ties on candidate index or genome hash. The same
//! seed therefore produces a byte-identical `SEARCH_pareto.json` at any
//! worker count (pinned by the integration suite). No `Instant`/
//! `SystemTime` anywhere in this subsystem — srclint R4 applies to all
//! four files.
//!
//! The search feeds the QoS ladder: [`to_rungs`] turns the front into
//! named `search-{i}` rungs and
//! `report::layerwise::qos_ladder_with_search` merges the ones no greedy
//! rung dominates into the governor's ladder via the order-independent
//! [`crate::qos::Ladder::sorted`] constructor.

pub mod evaluate;
pub mod genome;
pub mod nsga;

pub use evaluate::{check_feasible, EvalError, Evaluator, Objectives};
pub use genome::{Gene, Genome, GenomeError, Shape};
pub use nsga::{dominates, fast_nondominated_sort, hypervolume, survivors};

use std::collections::HashSet;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::datasets::Dataset;
use crate::nn::policy::MAX_M;
use crate::nn::Engine;
use crate::qos::Rung;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::threadpool::configured_workers;

use crate::approx::Polarity;

/// Tunables of one search run. CLI flags override the `CVAPPROX_SEARCH_*`
/// environment knobs, which override the defaults.
#[derive(Clone, Debug)]
pub struct SearchConfig {
    /// Number of generations after the seeded generation 0.
    pub generations: usize,
    /// Population size (survivor count per generation).
    pub pop: usize,
    /// The single seed every random draw derives from.
    pub seed: u64,
    /// Images of the evaluation set scored per candidate.
    pub n_images: usize,
    /// Systolic array width for the MAC-weighted power model.
    pub n_array: u32,
    /// Worker threads for fitness evaluation (objective values are
    /// identical at every setting; only wall-clock changes).
    pub workers: usize,
    /// Extra caller-provided seed genomes (e.g. the greedy ladder's
    /// policies re-encoded via [`Genome::from_policy`]).
    pub seeds: Vec<Genome>,
}

impl SearchConfig {
    /// Defaults only — no environment reads.
    pub fn new(n_images: usize) -> SearchConfig {
        SearchConfig {
            generations: 12,
            pop: 24,
            seed: 2024,
            n_images,
            n_array: 64,
            workers: configured_workers(),
            seeds: Vec::new(),
        }
    }

    /// Defaults overridden by the `CVAPPROX_SEARCH_GENERATIONS`,
    /// `CVAPPROX_SEARCH_POP` and `CVAPPROX_SEARCH_SEED` knobs (all
    /// registered in the README env registry).
    pub fn from_env(n_images: usize) -> SearchConfig {
        let mut cfg = SearchConfig::new(n_images);
        if let Some(g) = env_u64("CVAPPROX_SEARCH_GENERATIONS") {
            cfg.generations = g as usize;
        }
        if let Some(p) = env_u64("CVAPPROX_SEARCH_POP") {
            cfg.pop = (p as usize).max(2);
        }
        if let Some(s) = env_u64("CVAPPROX_SEARCH_SEED") {
            cfg.seed = s;
        }
        cfg
    }
}

fn env_u64(name: &str) -> Option<u64> {
    std::env::var(name).ok().and_then(|v| v.trim().parse().ok())
}

/// One member of the final Pareto front.
#[derive(Clone, Debug)]
pub struct FrontMember {
    pub genome: Genome,
    pub est_loss: f64,
    pub power_norm: f64,
    /// FNV-1a genome hash — the memo key and the artifact provenance id.
    pub hash: u64,
}

/// A completed search run: the front plus its provenance.
#[derive(Clone, Debug)]
pub struct SearchResult {
    /// Pareto front, sorted by power descending (est_loss, then hash, as
    /// tie-breaks) — ladder insertion order.
    pub front: Vec<FrontMember>,
    pub seed: u64,
    pub generations: usize,
    pub pop: usize,
    pub n_images: usize,
    pub n_array: u32,
    /// Distinct genomes actually evaluated (memo misses).
    pub evals: u64,
    /// Evaluations answered from the genome-hash memo.
    pub memo_hits: u64,
    pub exact_acc: f64,
}

impl SearchResult {
    /// The `SEARCH_pareto.json` document: provenance block + full front
    /// (hashes as hex strings — u64 does not survive a f64 JSON number).
    pub fn to_json(&self) -> Json {
        let provenance = Json::obj()
            .field("seed", format!("{}", self.seed))
            .field("generations", self.generations)
            .field("pop", self.pop)
            .field("n_images", self.n_images)
            .field("n_array", self.n_array)
            .field("evals", self.evals as i64)
            .field("memo_hits", self.memo_hits as i64)
            .field("exact_acc", self.exact_acc);
        let front = self
            .front
            .iter()
            .enumerate()
            .map(|(i, m)| {
                Json::obj()
                    .field("name", format!("search-{i}"))
                    .field("hash", format!("{:016x}", m.hash))
                    .field("est_loss", m.est_loss)
                    .field("power_norm", m.power_norm)
                    .field("describe", m.genome.describe())
                    .field("genome", m.genome.to_json())
            })
            .collect();
        Json::obj().field("provenance", provenance).field("front", Json::Arr(front))
    }
}

/// Parse the front out of a `SEARCH_pareto.json` document, re-validating
/// every genome against the structural bitmodel and its recorded hash.
/// A tampered or hand-edited artifact fails here with a typed/contextual
/// error — it can never reach the ladder or the engine.
pub fn parse_front(j: &Json) -> Result<Vec<FrontMember>> {
    let arr = j
        .get("front")
        .and_then(|f| f.as_arr())
        .context("search artifact missing \"front\" array")?;
    arr.iter()
        .enumerate()
        .map(|(i, e)| -> Result<FrontMember> {
            let genome = e
                .get("genome")
                .with_context(|| format!("front member {i} missing \"genome\""))
                .and_then(Genome::from_json)
                .with_context(|| format!("front member {i}"))?;
            genome
                .structural_check()
                .map_err(anyhow::Error::from)
                .with_context(|| format!("front member {i} failed structural re-validation"))?;
            let est_loss = e
                .get("est_loss")
                .and_then(|v| v.as_f64())
                .with_context(|| format!("front member {i} missing \"est_loss\""))?;
            let power_norm = e
                .get("power_norm")
                .and_then(|v| v.as_f64())
                .with_context(|| format!("front member {i} missing \"power_norm\""))?;
            let hash = genome.hash();
            if let Some(recorded) = e.get("hash").and_then(|h| h.as_str()) {
                let recorded = u64::from_str_radix(recorded, 16)
                    .with_context(|| format!("front member {i}: bad hash {recorded:?}"))?;
                if recorded != hash {
                    anyhow::bail!(
                        "front member {i}: recorded hash {recorded:016x} does not match \
                         its genome ({hash:016x})"
                    );
                }
            }
            Ok(FrontMember { genome, est_loss, power_norm, hash })
        })
        .collect()
}

/// Turn a front into named QoS rungs, power-descending (`search-0` is the
/// most power-hungry / most accurate searched point). Decoding re-runs
/// policy validation, so a front that validates here always installs.
pub fn to_rungs(front: &[FrontMember]) -> Result<Vec<Rung>> {
    let mut sorted: Vec<&FrontMember> = front.iter().collect();
    sorted.sort_by(|a, b| order_front(a, b));
    sorted
        .iter()
        .enumerate()
        .map(|(i, m)| {
            let policy = m
                .genome
                .to_policy()
                .with_context(|| format!("searched rung {i} ({:016x})", m.hash))?;
            Ok(Rung {
                name: format!("search-{i}"),
                est_loss: m.est_loss,
                power_norm: m.power_norm,
                policy: Arc::new(policy),
            })
        })
        .collect()
}

fn order_front(a: &FrontMember, b: &FrontMember) -> std::cmp::Ordering {
    b.power_norm
        .partial_cmp(&a.power_norm)
        .unwrap_or(std::cmp::Ordering::Equal)
        .then_with(|| {
            a.est_loss.partial_cmp(&b.est_loss).unwrap_or(std::cmp::Ordering::Equal)
        })
        .then_with(|| a.hash.cmp(&b.hash))
}

/// The directed half of generation 0: the exact genome, every
/// family × m uniform (as a Neg point with CV and as a mirrored pairing —
/// the paper's grid plus its pairing extension), and every single-layer
/// perforated variant (point and pair, the rest exact) — the same axes
/// the greedy searches walk, so the evolution starts at least as informed
/// as the baseline it must dominate.
pub fn directed_seeds(n_layers: usize) -> Vec<Genome> {
    let mut seeds = vec![Genome::exact(n_layers)];
    for shape in Shape::APPROX {
        for m in 1..=MAX_M {
            let point = Gene::approx(shape, m, Polarity::Neg, true, false);
            let pair = Gene::approx(shape, m, Polarity::Neg, true, true);
            seeds.push(Genome::uniform(point, n_layers));
            seeds.push(Genome::uniform(pair, n_layers));
        }
    }
    for layer in 0..n_layers {
        for m in 1..=MAX_M {
            for paired in [false, true] {
                let mut g = Genome::exact(n_layers);
                g.genes[layer] = Gene::approx(Shape::Rows, m, Polarity::Neg, true, paired);
                seeds.push(g);
            }
        }
    }
    seeds
}

fn push_unique(pop: &mut Vec<Genome>, seen: &mut HashSet<u64>, g: Genome, n_layers: usize) {
    let g = g.normalized();
    if g.len() == n_layers && seen.insert(g.hash()) {
        pop.push(g);
    }
}

/// Run the co-design search against an already-constructed evaluator.
/// Split out so benches/tests can inject [`Evaluator::with_exact_acc`].
pub fn run_search_with(ev: &Evaluator<'_>, cfg: &SearchConfig) -> Result<SearchResult> {
    let n_layers = ev.n_layers();
    let mut rng = Rng::new(cfg.seed);

    // Generation 0: directed seeds + caller seeds + random fill, deduped
    // by genome hash in insertion order.
    let mut pop: Vec<Genome> = Vec::new();
    let mut seen: HashSet<u64> = HashSet::new();
    for g in directed_seeds(n_layers) {
        push_unique(&mut pop, &mut seen, g, n_layers);
    }
    for g in &cfg.seeds {
        push_unique(&mut pop, &mut seen, g.clone(), n_layers);
    }
    for _ in 0..cfg.pop * 10 {
        if pop.len() >= cfg.pop {
            break;
        }
        push_unique(&mut pop, &mut seen, Genome::random(&mut rng, n_layers), n_layers);
    }

    // The archive accumulates every *feasible* evaluation ever made, in
    // deterministic submission order; the final front is computed over it
    // so no nondominated point can be lost to generational truncation.
    let mut archive: Vec<(Genome, Objectives)> = Vec::new();
    let mut archived: HashSet<u64> = HashSet::new();

    let mut objs = eval_into_archive(ev, cfg, &pop, &mut archive, &mut archived);
    for _generation in 0..cfg.generations {
        let (rank, crowd) = nsga::rank_and_crowding(&objs);
        let mut combined = pop.clone();
        let mut combined_seen: HashSet<u64> =
            combined.iter().map(|g| g.hash()).collect();
        let mut attempts = 0usize;
        while combined.len() < pop.len() + cfg.pop && attempts < cfg.pop * 20 {
            attempts += 1;
            let a = nsga::tournament(&mut rng, &rank, &crowd);
            let b = nsga::tournament(&mut rng, &rank, &crowd);
            let child =
                Genome::crossover(&pop[a], &pop[b], &mut rng).mutate(&mut rng).normalized();
            if combined_seen.insert(child.hash()) {
                combined.push(child);
            }
        }
        let cobjs = eval_into_archive(ev, cfg, &combined, &mut archive, &mut archived);
        let keep = nsga::survivors(&cobjs, cfg.pop);
        pop = keep.iter().map(|&i| combined[i].clone()).collect();
        objs = keep.iter().map(|&i| cobjs[i]).collect();
    }

    // Final front: front 0 of the whole archive, power-descending, exact
    // objective ties collapsed to the lowest-hash representative.
    let aobjs: Vec<Option<Objectives>> = archive.iter().map(|&(_, o)| Some(o)).collect();
    let fronts = nsga::fast_nondominated_sort(&aobjs);
    let mut front: Vec<FrontMember> = fronts
        .first()
        .map(|f| {
            f.iter()
                .map(|&i| FrontMember {
                    genome: archive[i].0.clone(),
                    est_loss: archive[i].1.est_loss,
                    power_norm: archive[i].1.power_norm,
                    hash: archive[i].0.hash(),
                })
                .collect()
        })
        .unwrap_or_default();
    front.sort_by(|a, b| order_front(a, b));
    front.dedup_by(|a, b| a.est_loss == b.est_loss && a.power_norm == b.power_norm);

    let (memo_hits, evals) = ev.memo_stats();
    Ok(SearchResult {
        front,
        seed: cfg.seed,
        generations: cfg.generations,
        pop: cfg.pop,
        n_images: cfg.n_images,
        n_array: cfg.n_array,
        evals,
        memo_hits,
        exact_acc: ev.exact_acc(),
    })
}

/// Run the co-design search for one (engine, dataset) pair.
pub fn run_search(engine: &Engine, ds: &Dataset, cfg: &SearchConfig) -> Result<SearchResult> {
    let ev = Evaluator::new(engine, ds, cfg.n_images, cfg.n_array)?;
    run_search_with(&ev, cfg)
}

fn eval_into_archive(
    ev: &Evaluator<'_>,
    cfg: &SearchConfig,
    genomes: &[Genome],
    archive: &mut Vec<(Genome, Objectives)>,
    archived: &mut HashSet<u64>,
) -> Vec<Option<Objectives>> {
    let results = ev.evaluate_all(genomes, cfg.workers);
    let objs: Vec<Option<Objectives>> =
        results.iter().map(|r| r.as_ref().ok().copied()).collect();
    for (g, o) in genomes.iter().zip(&objs) {
        if let Some(o) = o {
            if archived.insert(g.hash()) {
                archive.push((g.clone(), *o));
            }
        }
    }
    objs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn directed_seeds_are_structurally_valid_and_deduped() {
        let seeds = directed_seeds(3);
        assert!(seeds.contains(&Genome::exact(3)));
        let mut hashes = HashSet::new();
        for g in &seeds {
            assert_eq!(g.len(), 3);
            g.validate().unwrap();
            g.structural_check().unwrap();
            assert!(hashes.insert(g.hash()), "duplicate seed {}", g.describe());
        }
        // the paper grid (3 shapes × MAX_M levels × point/pair) + exact +
        // per-layer singles (layers × MAX_M × point/pair)
        let expected = 1 + 3 * MAX_M as usize * 2 + 3 * MAX_M as usize * 2;
        assert_eq!(seeds.len(), expected);
    }

    #[test]
    fn config_env_knobs_override_defaults() {
        let base = SearchConfig::new(64);
        assert_eq!((base.generations, base.pop, base.seed), (12, 24, 2024));
        std::env::set_var("CVAPPROX_SEARCH_GENERATIONS", "3");
        std::env::set_var("CVAPPROX_SEARCH_POP", "9");
        std::env::set_var("CVAPPROX_SEARCH_SEED", "77");
        let cfg = SearchConfig::from_env(32);
        std::env::remove_var("CVAPPROX_SEARCH_GENERATIONS");
        std::env::remove_var("CVAPPROX_SEARCH_POP");
        std::env::remove_var("CVAPPROX_SEARCH_SEED");
        assert_eq!((cfg.generations, cfg.pop, cfg.seed, cfg.n_images), (3, 9, 77, 32));
    }

    #[test]
    fn to_rungs_sorts_power_descending_and_names_in_order() {
        let lo = Genome::uniform(
            Gene::approx(Shape::Rows, 4, Polarity::Neg, true, true),
            2,
        );
        let hi = Genome::exact(2);
        let front = vec![
            FrontMember {
                genome: lo.clone(),
                est_loss: 0.05,
                power_norm: 0.6,
                hash: lo.hash(),
            },
            FrontMember {
                genome: hi.clone(),
                est_loss: 0.0,
                power_norm: 1.0,
                hash: hi.hash(),
            },
        ];
        let rungs = to_rungs(&front).unwrap();
        assert_eq!(rungs.len(), 2);
        assert_eq!(rungs[0].name, "search-0");
        assert_eq!(rungs[0].power_norm, 1.0);
        assert_eq!(rungs[1].name, "search-1");
        assert_eq!(rungs[1].power_norm, 0.6);
        assert_eq!(rungs[1].policy.paired_layers(), 2);
    }

    #[test]
    fn artifact_roundtrip_revalidates_genomes_and_hashes() {
        let g = Genome::uniform(
            Gene::approx(Shape::Cols, 3, Polarity::Neg, true, false),
            2,
        );
        let result = SearchResult {
            front: vec![FrontMember {
                genome: g.clone(),
                est_loss: 0.015625,
                power_norm: 0.75,
                hash: g.hash(),
            }],
            seed: 2024,
            generations: 12,
            pop: 24,
            n_images: 64,
            n_array: 64,
            evals: 10,
            memo_hits: 3,
            exact_acc: 1.0,
        };
        let text = result.to_json().render();
        let back = parse_front(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.len(), 1);
        assert_eq!(back[0].hash, g.hash());
        assert_eq!(back[0].est_loss, 0.015625);
        assert_eq!(back[0].genome, g);
        // a tampered hash is rejected
        let tampered = text.replace(&format!("{:016x}", g.hash()), "00000000deadbeef");
        assert!(parse_front(&Json::parse(&tampered).unwrap()).is_err());
        // a holey mask in the artifact is a typed load error, not a panic
        let holey = text.replace("\"mask\": 7", "\"mask\": 5");
        let err = parse_front(&Json::parse(&holey).unwrap()).unwrap_err();
        assert!(format!("{err:#}").contains("holey"), "{err:#}");
    }
}
