//! Candidate fitness evaluation: typed feasibility gating, the CV-epilogue
//! accuracy path, the MAC-weighted power model, and hash-keyed memoization.
//!
//! The order of checks is a correctness contract, not an optimization:
//! a genome is (1) mask-validated, (2) checked against every layer's i32
//! K-headroom ceiling ([`LayerAssignment::max_k`]), and only then (3)
//! decoded into a [`LayerPolicy`] and run through the standard
//! [`crate::report::accuracy::evaluate`] forward path. An infeasible-K
//! candidate therefore dies with a typed [`EvalError::InfeasibleK`] *at
//! evaluation* — it can never reach a GEMM whose accumulator headroom it
//! would overflow mid-batch.
//!
//! Fitness is memoized per genome hash (FNV-1a) under a mutex, and
//! batches parallelize across candidates over the shared thread pool
//! ([`crate::util::threadpool::par_map`], ordered results). Each
//! candidate evaluates single-threaded, so objective values are identical
//! at every worker count.

use std::collections::HashMap;
use std::fmt;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use super::genome::{Genome, GenomeError};
use crate::datasets::Dataset;
use crate::nn::{Engine, ForwardOpts};
use crate::report::accuracy::evaluate;
use crate::util::sync::lock_clean;
use crate::util::threadpool::par_map;

/// The two minimized objectives of a feasible candidate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Objectives {
    /// Estimated accuracy loss vs the exact design on the evaluation set
    /// (fraction, clamped at 0).
    pub est_loss: f64,
    /// MAC-weighted normalized power ([`crate::nn::LayerPolicy::power_norm`]).
    pub power_norm: f64,
}

/// Typed evaluation failure. Infeasible candidates stay in the population
/// (ranked behind every feasible front) instead of aborting the search.
#[derive(Clone, Debug, PartialEq)]
pub enum EvalError {
    /// The genome failed mask/structural validation.
    Invalid(GenomeError),
    /// Layer `layer` reduces over `k` elements but the candidate's
    /// assignment only guarantees i32 headroom up to `max_k`.
    InfeasibleK { layer: usize, k: usize, max_k: usize },
    /// The decoded policy failed to build or to evaluate.
    Eval(String),
}

impl fmt::Display for EvalError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EvalError::Invalid(e) => write!(f, "invalid genome: {e}"),
            EvalError::InfeasibleK { layer, k, max_k } => write!(
                f,
                "layer {layer} reduces over K = {k}, above the i32-headroom \
                 ceiling {max_k} of its assignment"
            ),
            EvalError::Eval(msg) => write!(f, "evaluation failed: {msg}"),
        }
    }
}

impl std::error::Error for EvalError {}

impl From<GenomeError> for EvalError {
    fn from(e: GenomeError) -> EvalError {
        EvalError::Invalid(e)
    }
}

/// Pure feasibility gate: mask validation plus the per-layer K-headroom
/// check against the model's reduction depths. Runs before any forward.
pub fn check_feasible(genome: &Genome, kdims: &[usize]) -> Result<(), EvalError> {
    genome.validate()?;
    if genome.len() != kdims.len() {
        return Err(EvalError::Invalid(GenomeError::LayerCount {
            expected: kdims.len(),
            got: genome.len(),
        }));
    }
    for (layer, (gene, &k)) in genome.genes.iter().zip(kdims).enumerate() {
        let max_k = gene.to_assignment().max_k();
        if k > max_k {
            return Err(EvalError::InfeasibleK { layer, k, max_k });
        }
    }
    Ok(())
}

struct MemoState {
    map: HashMap<u64, Result<Objectives, EvalError>>,
    hits: u64,
    misses: u64,
}

/// Shared fitness evaluator for one (engine, dataset) pair.
pub struct Evaluator<'a> {
    engine: &'a Engine,
    ds: &'a Dataset,
    n_images: usize,
    n_array: u32,
    exact_acc: f64,
    kdims: Vec<usize>,
    memo: Mutex<MemoState>,
}

impl<'a> Evaluator<'a> {
    /// Build an evaluator, measuring the exact baseline accuracy once.
    pub fn new(
        engine: &'a Engine,
        ds: &'a Dataset,
        n_images: usize,
        n_array: u32,
    ) -> Result<Evaluator<'a>> {
        let exact_acc = evaluate(engine, ds, &ForwardOpts::exact(), n_images, 1)?;
        Ok(Self::with_exact_acc(engine, ds, n_images, n_array, exact_acc))
    }

    /// Build an evaluator around an already-measured exact baseline (no
    /// forward pass — what the infeasibility tests use so rejection can be
    /// observed without any GEMM ever running).
    pub fn with_exact_acc(
        engine: &'a Engine,
        ds: &'a Dataset,
        n_images: usize,
        n_array: u32,
        exact_acc: f64,
    ) -> Evaluator<'a> {
        Evaluator {
            engine,
            ds,
            n_images,
            n_array,
            exact_acc,
            kdims: engine.model.mac_layer_kdims(),
            memo: Mutex::new(MemoState { map: HashMap::new(), hits: 0, misses: 0 }),
        }
    }

    pub fn exact_acc(&self) -> f64 {
        self.exact_acc
    }

    pub fn n_layers(&self) -> usize {
        self.kdims.len()
    }

    /// `(memo hits, actual evaluations)` so far.
    pub fn memo_stats(&self) -> (u64, u64) {
        let memo = lock_clean(&self.memo);
        (memo.hits, memo.misses)
    }

    fn compute(&self, genome: &Genome) -> Result<Objectives, EvalError> {
        check_feasible(genome, &self.kdims)?;
        let policy =
            genome.to_policy().map_err(|e| EvalError::Eval(format!("{e:#}")))?;
        let power_norm = policy.power_norm(&self.engine.model, self.n_array);
        let acc = evaluate(
            self.engine,
            self.ds,
            &ForwardOpts::with_policy(Arc::new(policy)),
            self.n_images,
            1,
        )
        .map_err(|e| EvalError::Eval(format!("{e:#}")))?;
        Ok(Objectives { est_loss: (self.exact_acc - acc).max(0.0), power_norm })
    }

    /// Evaluate one genome, memoized by its FNV-1a hash.
    pub fn evaluate_genome(&self, genome: &Genome) -> Result<Objectives, EvalError> {
        let h = genome.hash();
        {
            let mut memo = lock_clean(&self.memo);
            if let Some(r) = memo.map.get(&h) {
                memo.hits += 1;
                return r.clone();
            }
        }
        // Computed outside the lock: a second thread racing on the same
        // hash recomputes the identical pure result, which is cheaper than
        // serializing every forward behind the memo mutex.
        let r = self.compute(genome);
        let mut memo = lock_clean(&self.memo);
        memo.misses += 1;
        memo.map.insert(h, r.clone());
        r
    }

    /// Evaluate a batch in parallel over the shared pool. Results come
    /// back in input order regardless of worker count.
    pub fn evaluate_all(
        &self,
        genomes: &[Genome],
        workers: usize,
    ) -> Vec<Result<Objectives, EvalError>> {
        par_map(genomes.len(), workers, |i| self.evaluate_genome(&genomes[i]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::Polarity;
    use crate::nn::gemm::{MAX_K_NEG, MAX_K_POS};
    use crate::search::genome::{Gene, Shape};

    #[test]
    fn feasibility_gate_is_typed_and_ordered() {
        // mask validation fires before the K check
        let mut holey = Genome::exact(2);
        holey.genes[0] = Gene {
            mask: 0b101,
            ..Gene::approx(Shape::Rows, 1, Polarity::Neg, true, false)
        };
        assert!(matches!(
            check_feasible(&holey, &[10, 10]),
            Err(EvalError::Invalid(GenomeError::Mask { layer: 0, .. }))
        ));
        // layer-count mismatch is typed
        assert!(matches!(
            check_feasible(&Genome::exact(2), &[10, 10, 10]),
            Err(EvalError::Invalid(GenomeError::LayerCount { expected: 3, got: 2 }))
        ));
        // a Pos-polarity point has the tighter ceiling
        let mut pos = Genome::exact(2);
        pos.genes[1] = Gene::approx(Shape::Cols, 2, Polarity::Pos, true, false);
        let k_over_pos = MAX_K_POS + 1;
        match check_feasible(&pos, &[10, k_over_pos]) {
            Err(EvalError::InfeasibleK { layer: 1, k, max_k }) => {
                assert_eq!(k, k_over_pos);
                assert_eq!(max_k, MAX_K_POS);
            }
            other => panic!("wrong result {other:?}"),
        }
        // the same depth under a Neg point is fine
        let mut neg = pos.clone();
        neg.genes[1] = Gene::approx(Shape::Cols, 2, Polarity::Neg, true, false);
        assert!(check_feasible(&neg, &[10, k_over_pos]).is_ok());
        // a mirrored pair inherits the tighter (Pos) half's ceiling
        let mut pair = Genome::exact(2);
        pair.genes[1] = Gene::approx(Shape::Rows, 1, Polarity::Neg, true, true);
        assert!(matches!(
            check_feasible(&pair, &[10, k_over_pos]),
            Err(EvalError::InfeasibleK { layer: 1, .. })
        ));
        // nothing is feasible beyond the Neg ceiling either
        assert!(matches!(
            check_feasible(&Genome::exact(1), &[MAX_K_NEG + 1]),
            Err(EvalError::InfeasibleK { .. })
        ));
    }
}
