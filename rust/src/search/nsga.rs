//! Deterministic NSGA-II machinery: fast non-dominated sort, crowding
//! distance, survivor selection and binary tournaments over the
//! two-objective (est. accuracy loss, normalized power) plane.
//!
//! Everything here is pure integer/float bookkeeping with explicit index
//! tie-breaks, so the same inputs produce the same fronts on every
//! platform and at every thread count — the property the byte-identical
//! `SEARCH_pareto.json` tests pin. Infeasible candidates (`None`
//! objectives: K-headroom or validation failures) are not discarded but
//! ranked together *behind* every feasible front, the standard
//! constraint-domination treatment.
//!
//! `scripts/search_mirror.py` transliterates this module and cross-checks
//! it against the fixture front in `rust/tests/fixtures/search_front.json`
//! — keep the two in lockstep.

use super::evaluate::Objectives;
use crate::util::rng::Rng;

/// Strict Pareto dominance on (est_loss, power_norm), both minimized:
/// `a` is no worse on both axes and strictly better on at least one.
pub fn dominates(a: Objectives, b: Objectives) -> bool {
    a.est_loss <= b.est_loss
        && a.power_norm <= b.power_norm
        && (a.est_loss < b.est_loss || a.power_norm < b.power_norm)
}

/// Fast non-dominated sort. Returns fronts of candidate indices, each
/// front in ascending index order; front 0 is the Pareto front of the
/// feasible candidates. All infeasible candidates form one final front.
pub fn fast_nondominated_sort(objs: &[Option<Objectives>]) -> Vec<Vec<usize>> {
    let feasible: Vec<usize> = (0..objs.len()).filter(|&i| objs[i].is_some()).collect();
    let infeasible: Vec<usize> = (0..objs.len()).filter(|&i| objs[i].is_none()).collect();
    let mut fronts: Vec<Vec<usize>> = Vec::new();
    if !feasible.is_empty() {
        let mut dominated_by = vec![0usize; objs.len()];
        let mut dominates_list: Vec<Vec<usize>> = vec![Vec::new(); objs.len()];
        for (ai, &a) in feasible.iter().enumerate() {
            for &b in &feasible[ai + 1..] {
                let (oa, ob) = (objs[a].unwrap(), objs[b].unwrap());
                if dominates(oa, ob) {
                    dominates_list[a].push(b);
                    dominated_by[b] += 1;
                } else if dominates(ob, oa) {
                    dominates_list[b].push(a);
                    dominated_by[a] += 1;
                }
            }
        }
        let mut current: Vec<usize> =
            feasible.iter().copied().filter(|&i| dominated_by[i] == 0).collect();
        while !current.is_empty() {
            let mut next: Vec<usize> = Vec::new();
            for &i in &current {
                for &j in &dominates_list[i] {
                    dominated_by[j] -= 1;
                    if dominated_by[j] == 0 {
                        next.push(j);
                    }
                }
            }
            next.sort_unstable();
            fronts.push(std::mem::replace(&mut current, next));
        }
    }
    if !infeasible.is_empty() {
        fronts.push(infeasible);
    }
    fronts
}

/// Crowding distance of one front, aligned with `front`'s positions.
/// Boundary members get `f64::INFINITY`; interior members accumulate the
/// normalized neighbour gap per objective. Objective sorts tie-break on
/// candidate index, so equal-objective members get deterministic
/// distances. An all-infeasible front has no objectives to spread over —
/// every member gets `INFINITY` (truncation then falls back to index
/// order).
pub fn crowding_distance(objs: &[Option<Objectives>], front: &[usize]) -> Vec<f64> {
    let mut d = vec![0.0f64; front.len()];
    if front.is_empty() {
        return d;
    }
    if objs[front[0]].is_none() {
        return vec![f64::INFINITY; front.len()];
    }
    for axis in 0..2 {
        let value = |pos: usize| -> f64 {
            let o = objs[front[pos]].unwrap();
            if axis == 0 {
                o.est_loss
            } else {
                o.power_norm
            }
        };
        let mut order: Vec<usize> = (0..front.len()).collect();
        order.sort_by(|&a, &b| {
            value(a)
                .partial_cmp(&value(b))
                .unwrap()
                .then_with(|| front[a].cmp(&front[b]))
        });
        let (first, last) = (order[0], order[order.len() - 1]);
        d[first] = f64::INFINITY;
        d[last] = f64::INFINITY;
        let range = value(last) - value(first);
        if range > 0.0 {
            for w in order.windows(3) {
                let (prev, mid, next) = (w[0], w[1], w[2]);
                d[mid] += (value(next) - value(prev)) / range;
            }
        }
    }
    d
}

/// Per-candidate (rank, crowding) over the whole population: rank is the
/// front number; crowding is within that front.
pub fn rank_and_crowding(objs: &[Option<Objectives>]) -> (Vec<usize>, Vec<f64>) {
    let fronts = fast_nondominated_sort(objs);
    let mut rank = vec![usize::MAX; objs.len()];
    let mut crowd = vec![0.0f64; objs.len()];
    for (r, front) in fronts.iter().enumerate() {
        let d = crowding_distance(objs, front);
        for (pos, &i) in front.iter().enumerate() {
            rank[i] = r;
            crowd[i] = d[pos];
        }
    }
    (rank, crowd)
}

/// Elitist survivor selection: take whole fronts (in index order) while
/// they fit, then fill the remainder from the next front by crowding
/// distance descending, ties broken by ascending index.
pub fn survivors(objs: &[Option<Objectives>], n: usize) -> Vec<usize> {
    let mut keep: Vec<usize> = Vec::with_capacity(n);
    for front in fast_nondominated_sort(objs) {
        if keep.len() >= n {
            break;
        }
        let room = n - keep.len();
        if front.len() <= room {
            keep.extend(front);
            continue;
        }
        let d = crowding_distance(objs, &front);
        let mut order: Vec<usize> = (0..front.len()).collect();
        order.sort_by(|&a, &b| {
            d[b].partial_cmp(&d[a]).unwrap().then_with(|| front[a].cmp(&front[b]))
        });
        keep.extend(order[..room].iter().map(|&pos| front[pos]));
    }
    keep
}

/// Binary tournament on (rank asc, crowding desc, index asc).
pub fn tournament(rng: &mut Rng, rank: &[usize], crowd: &[f64]) -> usize {
    let a = rng.below(rank.len() as u64) as usize;
    let b = rng.below(rank.len() as u64) as usize;
    if rank[a] != rank[b] {
        return if rank[a] < rank[b] { a } else { b };
    }
    if crowd[a] != crowd[b] {
        return if crowd[a] > crowd[b] { a } else { b };
    }
    a.min(b)
}

/// 2-D hypervolume of a candidate set against a reference point that both
/// objectives stay below: the area the set's Pareto front carves out of
/// the rectangle toward `(ref_loss, ref_power)`. Members outside the
/// reference box contribute nothing.
pub fn hypervolume(points: &[Objectives], ref_loss: f64, ref_power: f64) -> f64 {
    let mut pts: Vec<Objectives> = points
        .iter()
        .copied()
        .filter(|p| p.est_loss < ref_loss && p.power_norm < ref_power)
        .collect();
    pts.sort_by(|a, b| {
        a.est_loss
            .partial_cmp(&b.est_loss)
            .unwrap()
            .then_with(|| a.power_norm.partial_cmp(&b.power_norm).unwrap())
    });
    let mut hv = 0.0;
    let mut best_power = ref_power;
    for p in pts {
        if p.power_norm < best_power {
            hv += (ref_loss - p.est_loss) * (best_power - p.power_norm);
            best_power = p.power_norm;
        }
    }
    hv
}

#[cfg(test)]
mod tests {
    use super::*;

    fn o(loss: f64, power: f64) -> Option<Objectives> {
        Some(Objectives { est_loss: loss, power_norm: power })
    }

    #[test]
    fn dominance_is_strict_and_partial() {
        let a = Objectives { est_loss: 0.0, power_norm: 0.5 };
        let b = Objectives { est_loss: 0.25, power_norm: 0.5 };
        let c = Objectives { est_loss: 0.5, power_norm: 0.25 };
        assert!(dominates(a, b));
        assert!(!dominates(b, a));
        assert!(!dominates(a, a), "equal points do not dominate each other");
        assert!(!dominates(b, c) && !dominates(c, b), "incomparable pair");
    }

    #[test]
    fn sort_ranks_infeasible_last() {
        let objs = vec![o(0.0, 1.0), None, o(0.5, 0.5), o(0.5, 0.75), None];
        let fronts = fast_nondominated_sort(&objs);
        assert_eq!(fronts, vec![vec![0, 2], vec![3], vec![1, 4]]);
        let (rank, _) = rank_and_crowding(&objs);
        assert_eq!(rank, vec![0, 2, 0, 1, 2]);
    }

    #[test]
    fn crowding_boundaries_are_infinite_and_interior_exact() {
        // Objectives on exact binary fractions so the expected distances
        // are exact — the same numbers the python mirror checks.
        let objs = vec![o(0.0, 1.125), o(0.125, 0.75), o(0.25, 0.5), o(0.5, 0.25), o(1.0, 0.125)];
        let front: Vec<usize> = (0..5).collect();
        let d = crowding_distance(&objs, &front);
        assert_eq!(d[0], f64::INFINITY);
        assert_eq!(d[4], f64::INFINITY);
        assert_eq!(d[1], 0.875);
        assert_eq!(d[2], 0.875);
        assert_eq!(d[3], 1.125);
    }

    #[test]
    fn survivor_truncation_prefers_spread_then_index() {
        let objs = vec![o(0.0, 1.125), o(0.125, 0.75), o(0.25, 0.5), o(0.5, 0.25), o(1.0, 0.125)];
        assert_eq!(survivors(&objs, 5), vec![0, 1, 2, 3, 4]);
        // boundaries first (index tie-break 0 before 4), then d=1.125,
        // then the 0.875 tie resolved by index.
        assert_eq!(survivors(&objs, 4), vec![0, 4, 3, 1]);
        assert_eq!(survivors(&objs, 2), vec![0, 4]);
    }

    #[test]
    fn tournament_is_deterministic_per_seed() {
        let objs = vec![o(0.0, 1.0), o(0.5, 0.5), o(0.75, 0.75), None];
        let (rank, crowd) = rank_and_crowding(&objs);
        let picks: Vec<usize> = {
            let mut rng = Rng::new(11);
            (0..20).map(|_| tournament(&mut rng, &rank, &crowd)).collect()
        };
        let again: Vec<usize> = {
            let mut rng = Rng::new(11);
            (0..20).map(|_| tournament(&mut rng, &rank, &crowd)).collect()
        };
        assert_eq!(picks, again);
        // the infeasible candidate (worst rank) never beats a feasible one
        // it is drawn against
        let mut rng = Rng::new(3);
        for _ in 0..200 {
            let w = tournament(&mut rng, &rank, &crowd);
            assert!(w < 3 || rank[w] == rank.iter().copied().max().unwrap());
        }
    }

    #[test]
    fn hypervolume_rewards_wider_fronts() {
        let narrow = [Objectives { est_loss: 0.0, power_norm: 0.5 }];
        let wide = [
            Objectives { est_loss: 0.0, power_norm: 0.5 },
            Objectives { est_loss: 0.25, power_norm: 0.25 },
        ];
        let hn = hypervolume(&narrow, 1.0, 1.25);
        let hw = hypervolume(&wide, 1.0, 1.25);
        assert_eq!(hn, 0.75);
        assert_eq!(hw, 0.75 + 0.75 * 0.25);
        assert!(hw > hn);
        // points outside the reference box contribute nothing
        let dom = [Objectives { est_loss: 2.0, power_norm: 2.0 }];
        assert_eq!(hypervolume(&dom, 1.0, 1.25), 0.0);
    }
}
