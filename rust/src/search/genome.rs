//! Genome encoding for the multiplier/assignment co-design search.
//!
//! One [`Gene`] per MAC layer describes *which partial products the
//! layer's multipliers drop* and *how the layer is assigned*:
//!
//! * `shape` — which structural dimension of the 8×8 AND array the drop
//!   mask removes: whole PP **rows** (the perforated family), low product
//!   **columns** (the truncated family), or the low×low **sub-array**
//!   (the recursive family). `Exact` drops nothing.
//! * `mask` — the per-column/row drop mask. Bit *i* set means position
//!   *i* is never generated; `m = mask.count_ones()` recovers the
//!   family's approximation level. Only contiguous low prefixes
//!   (`0b1`, `0b11`, …, `0b111_1111`) are structurally realizable — a
//!   holey mask would leave floating compressor inputs in the Dadda
//!   tree — so anything else is a typed [`GenomeError`], never a panic.
//! * `polarity` — round-down ([`Polarity::Neg`], the paper's ε ≥ 0
//!   designs) or the round-up mirror ([`Polarity::Pos`]).
//! * `paired` — run the layer as a mirrored Neg/Pos pair
//!   ([`PairedPoint::mirrored`]) so accumulated error cancels.
//! * `use_cv` — add the control-variate epilogue.
//!
//! [`Genome::structural_check`] re-derives every gene against the
//! structural models: the masked Dadda column heights must account for
//! exactly the dropped partial products ([`crate::hw::dadda`]), and the
//! gate-level AND-array model must agree with the fast arithmetic
//! multiplier on sampled operands ([`crate::approx::bitmodel`]).

use std::fmt;

use crate::approx::{am_pol, bitmodel, Family, Polarity};
use crate::hw::dadda;
use crate::nn::policy::MAX_M;
use crate::nn::{LayerAssignment, LayerPoint, LayerPolicy, PairedPoint};
use crate::util::hash::Hasher64;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Typed genome validation failure. The search and the `qos-ladder
/// --search` loader surface these as errors instead of panicking on a
/// malformed candidate or artifact.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum GenomeError {
    /// A genome must carry one gene per MAC layer; zero genes is not a
    /// policy.
    Empty,
    /// Gene count does not match the model's MAC layer count.
    LayerCount { expected: usize, got: usize },
    /// The drop mask is not structurally realizable (see variants of
    /// `reason`: holey, too wide, or inconsistent with the exact shape).
    Mask { layer: usize, mask: u8, reason: &'static str },
    /// The gene failed re-validation against the `dadda`/`bitmodel`
    /// structural circuit models.
    Structural { layer: usize, detail: String },
}

impl fmt::Display for GenomeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            GenomeError::Empty => write!(f, "genome has no genes"),
            GenomeError::LayerCount { expected, got } => write!(
                f,
                "genome has {got} genes but the model has {expected} MAC layers"
            ),
            GenomeError::Mask { layer, mask, reason } => {
                write!(f, "gene {layer}: drop mask {mask:#010b} invalid: {reason}")
            }
            GenomeError::Structural { layer, detail } => {
                write!(f, "gene {layer}: structural model mismatch: {detail}")
            }
        }
    }
}

impl std::error::Error for GenomeError {}

/// Which structural dimension of the partial-product array the drop mask
/// removes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Shape {
    /// Nothing dropped: the exact multiplier.
    Exact,
    /// Drop whole PP rows (the perforated family, paper Fig. 1b).
    Rows,
    /// Drop low product columns (the truncated family, paper Fig. 3).
    Cols,
    /// Prune the low×low sub-product (the recursive family).
    SubArray,
}

impl Shape {
    pub const APPROX: [Shape; 3] = [Shape::Rows, Shape::Cols, Shape::SubArray];

    pub fn name(self) -> &'static str {
        match self {
            Shape::Exact => "exact",
            Shape::Rows => "rows",
            Shape::Cols => "cols",
            Shape::SubArray => "subarray",
        }
    }

    pub fn from_name(name: &str) -> Option<Shape> {
        match name {
            "exact" => Some(Shape::Exact),
            "rows" => Some(Shape::Rows),
            "cols" => Some(Shape::Cols),
            "subarray" => Some(Shape::SubArray),
            _ => None,
        }
    }

    /// The multiplier family this drop dimension realizes.
    pub fn family(self) -> Family {
        match self {
            Shape::Exact => Family::Exact,
            Shape::Rows => Family::Perforated,
            Shape::Cols => Family::Truncated,
            Shape::SubArray => Family::Recursive,
        }
    }

    pub fn from_family(family: Family) -> Shape {
        match family {
            Family::Exact => Shape::Exact,
            Family::Perforated => Shape::Rows,
            Family::Truncated => Shape::Cols,
            Family::Recursive => Shape::SubArray,
        }
    }

    fn code(self) -> u64 {
        match self {
            Shape::Exact => 0,
            Shape::Rows => 1,
            Shape::Cols => 2,
            Shape::SubArray => 3,
        }
    }
}

/// The contiguous low-prefix mask dropping `m` positions.
pub fn prefix_mask(m: u32) -> u8 {
    ((1u32 << m.min(MAX_M)) - 1) as u8
}

/// One layer's slot in the genome (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Gene {
    pub shape: Shape,
    pub mask: u8,
    pub polarity: Polarity,
    pub use_cv: bool,
    pub paired: bool,
}

impl Gene {
    /// The exact gene in normal form: nothing dropped, canonical flags.
    pub fn exact() -> Gene {
        Gene {
            shape: Shape::Exact,
            mask: 0,
            polarity: Polarity::Neg,
            use_cv: false,
            paired: false,
        }
    }

    /// A non-exact gene dropping `m` positions of `shape`.
    pub fn approx(shape: Shape, m: u32, polarity: Polarity, use_cv: bool, paired: bool) -> Gene {
        Gene { shape, mask: prefix_mask(m), polarity, use_cv, paired }.normalized()
    }

    /// Approximation level: how many rows/columns/sub-positions the mask
    /// drops.
    pub fn m(self) -> u32 {
        self.mask.count_ones()
    }

    /// Canonical form: an empty mask (or the exact shape) collapses to
    /// [`Gene::exact`]; a mirrored pair carries both polarities, so its
    /// stored polarity is canonically `Neg`.
    pub fn normalized(self) -> Gene {
        if self.shape == Shape::Exact || self.mask == 0 {
            Gene::exact()
        } else if self.paired {
            Gene { polarity: Polarity::Neg, ..self }
        } else {
            self
        }
    }

    /// Mask-level validation: typed errors for every structurally
    /// unrealizable encoding (holey masks in particular).
    pub fn validate(self, layer: usize) -> Result<(), GenomeError> {
        if self.shape == Shape::Exact {
            if self.mask != 0 {
                return Err(GenomeError::Mask {
                    layer,
                    mask: self.mask,
                    reason: "the exact shape drops nothing, so its mask must be empty",
                });
            }
            if self.paired || self.use_cv || self.polarity != Polarity::Neg {
                return Err(GenomeError::Mask {
                    layer,
                    mask: self.mask,
                    reason: "exact gene out of normal form (pair/CV/polarity flags set)",
                });
            }
            return Ok(());
        }
        if self.mask == 0 {
            return Err(GenomeError::Mask {
                layer,
                mask: self.mask,
                reason: "an approximate gene must drop at least one position",
            });
        }
        let m = self.m();
        if m > MAX_M {
            return Err(GenomeError::Mask {
                layer,
                mask: self.mask,
                reason: "mask drops more than MAX_M positions",
            });
        }
        if self.mask != prefix_mask(m) {
            return Err(GenomeError::Mask {
                layer,
                mask: self.mask,
                reason: "holey drop mask: only a contiguous low prefix leaves a \
                         reducible Dadda array",
            });
        }
        Ok(())
    }

    /// Decode into the runtime assignment the engine executes.
    pub fn to_assignment(self) -> LayerAssignment {
        let g = self.normalized();
        if g.shape == Shape::Exact {
            return LayerAssignment::Point(LayerPoint::EXACT);
        }
        let family = g.shape.family();
        if g.paired {
            LayerAssignment::Paired(PairedPoint::mirrored(family, g.m(), g.use_cv))
        } else {
            LayerAssignment::Point(LayerPoint::new_pol(family, g.m(), g.polarity, g.use_cv))
        }
    }

    /// Re-encode a runtime assignment. Returns `None` for assignments the
    /// genome cannot express (non-mirrored pairings).
    pub fn from_assignment(a: LayerAssignment) -> Option<Gene> {
        match a.normalized() {
            LayerAssignment::Point(p) if p == LayerPoint::EXACT => Some(Gene::exact()),
            LayerAssignment::Point(p) => Some(Gene {
                shape: Shape::from_family(p.family),
                mask: prefix_mask(p.m),
                polarity: p.polarity,
                use_cv: p.use_cv,
                paired: false,
            }),
            LayerAssignment::Paired(p) => {
                let mirrored = p.even.family == p.odd.family
                    && p.even.m == p.odd.m
                    && p.even.use_cv == p.odd.use_cv
                    && p.even.polarity == Polarity::Neg
                    && p.odd.polarity == Polarity::Pos;
                if !mirrored {
                    return None;
                }
                Some(Gene {
                    shape: Shape::from_family(p.even.family),
                    mask: prefix_mask(p.even.m),
                    polarity: Polarity::Neg,
                    use_cv: p.even.use_cv,
                    paired: true,
                })
            }
        }
    }

    fn pack(self) -> u64 {
        let g = self.normalized();
        g.shape.code()
            | (g.mask as u64) << 8
            | (match g.polarity {
                Polarity::Neg => 0u64,
                Polarity::Pos => 1,
            }) << 16
            | (g.use_cv as u64) << 24
            | (g.paired as u64) << 25
    }

    fn to_json(self) -> Json {
        Json::obj()
            .field("shape", self.shape.name())
            .field("mask", self.mask as i64)
            .field(
                "polarity",
                match self.polarity {
                    Polarity::Neg => "neg",
                    Polarity::Pos => "pos",
                },
            )
            .field("cv", self.use_cv)
            .field("paired", self.paired)
    }

    fn from_json(j: &Json, layer: usize) -> anyhow::Result<Gene> {
        use anyhow::Context;
        let shape = j
            .get("shape")
            .and_then(|s| s.as_str())
            .and_then(Shape::from_name)
            .with_context(|| format!("gene {layer}: bad or missing \"shape\""))?;
        let mask = j
            .get("mask")
            .and_then(|m| m.as_f64())
            .with_context(|| format!("gene {layer}: missing \"mask\""))?;
        if !(0.0..=255.0).contains(&mask) || mask.fract() != 0.0 {
            anyhow::bail!("gene {layer}: mask {mask} is not a byte");
        }
        let polarity = match j.get("polarity").and_then(|p| p.as_str()) {
            Some("neg") | None => Polarity::Neg,
            Some("pos") => Polarity::Pos,
            Some(other) => anyhow::bail!("gene {layer}: unknown polarity {other:?}"),
        };
        let use_cv = j.get("cv").and_then(|c| c.as_bool()).unwrap_or(false);
        let paired = j.get("paired").and_then(|c| c.as_bool()).unwrap_or(false);
        Ok(Gene { shape, mask: mask as u8, polarity, use_cv, paired })
    }
}

/// A full per-layer drop-mask configuration: one [`Gene`] per MAC layer.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Genome {
    pub genes: Vec<Gene>,
}

impl Genome {
    pub fn exact(n_layers: usize) -> Genome {
        Genome { genes: vec![Gene::exact(); n_layers.max(1)] }
    }

    pub fn uniform(gene: Gene, n_layers: usize) -> Genome {
        Genome { genes: vec![gene.normalized(); n_layers.max(1)] }
    }

    pub fn len(&self) -> usize {
        self.genes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.genes.is_empty()
    }

    /// FNV-1a digest of the normalized genome — the memoization key and
    /// the artifact provenance id.
    pub fn hash(&self) -> u64 {
        let mut h = Hasher64::new();
        h.word(self.genes.len() as u64);
        for g in &self.genes {
            h.word(g.pack());
        }
        h.finish()
    }

    pub fn normalized(&self) -> Genome {
        Genome { genes: self.genes.iter().map(|g| g.normalized()).collect() }
    }

    /// Mask-level validation of every gene (typed, no panics).
    pub fn validate(&self) -> Result<(), GenomeError> {
        if self.genes.is_empty() {
            return Err(GenomeError::Empty);
        }
        for (layer, g) in self.genes.iter().enumerate() {
            g.validate(layer)?;
        }
        Ok(())
    }

    /// Full structural re-validation: masks must be realizable, the
    /// masked Dadda column heights must drop exactly the masked partial
    /// products, and the gate-level AND-array model must agree with the
    /// arithmetic multiplier on operands sampled from a genome-seeded
    /// stream (so the check itself is deterministic per genome).
    pub fn structural_check(&self) -> Result<(), GenomeError> {
        self.validate()?;
        let full = dadda::reduce(&dadda::full_heights(8));
        for (layer, g) in self.genes.iter().enumerate() {
            let g = g.normalized();
            if g.shape == Shape::Exact {
                continue;
            }
            let m = g.m();
            // Dadda height accounting: rows drop m full 8-bit PP rows,
            // cols drop the m low columns (heights 1..=m). The recursive
            // sub-array has no column-mask equivalent, so it is covered
            // by the AND-array sampling below only.
            let dropped = match g.shape {
                Shape::Rows => Some((dadda::perforated_heights(8, m), 8 * m)),
                Shape::Cols => Some((dadda::truncated_heights(8, m), m * (m + 1) / 2)),
                _ => None,
            };
            if let Some((heights, want_dropped)) = dropped {
                let red = dadda::reduce(&heights);
                if red.pp_bits + want_dropped != full.pp_bits {
                    return Err(GenomeError::Structural {
                        layer,
                        detail: format!(
                            "{} m={m}: masked array keeps {} pp bits, expected {}",
                            g.shape.name(),
                            red.pp_bits,
                            full.pp_bits - want_dropped
                        ),
                    });
                }
                if red.stages > full.stages {
                    return Err(GenomeError::Structural {
                        layer,
                        detail: format!(
                            "{} m={m}: masked reduction takes {} stages, exact takes {}",
                            g.shape.name(),
                            red.stages,
                            full.stages
                        ),
                    });
                }
            }
            // Gate-level / arithmetic agreement on sampled operands.
            let family = g.shape.family();
            let polarities: &[Polarity] = if g.paired {
                &[Polarity::Neg, Polarity::Pos]
            } else {
                std::slice::from_ref(&g.polarity)
            };
            let mut rng = Rng::new(self.hash() ^ (layer as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15));
            for _ in 0..32 {
                let (w, a) = (rng.u8(), rng.u8());
                for &pol in polarities {
                    let bits = bitmodel::am_bits_pol(family, pol, w, a, m);
                    let fast = am_pol(family, pol, w, a, m);
                    if bits != fast {
                        return Err(GenomeError::Structural {
                            layer,
                            detail: format!(
                                "{} m={m} pol={pol:?}: AND-array model gives {bits} \
                                 for {w}*{a}, arithmetic model gives {fast}",
                                g.shape.name()
                            ),
                        });
                    }
                }
            }
        }
        Ok(())
    }

    /// Decode into the runtime [`LayerPolicy`] (validating first).
    pub fn to_policy(&self) -> anyhow::Result<LayerPolicy> {
        self.validate()?;
        LayerPolicy::from_assignments(self.genes.iter().map(|g| g.to_assignment()).collect())
    }

    /// Re-encode a runtime policy. `None` when the policy uses an
    /// assignment the genome cannot express (a non-mirrored pairing).
    pub fn from_policy(policy: &LayerPolicy) -> Option<Genome> {
        let genes: Option<Vec<Gene>> =
            policy.assignments().map(Gene::from_assignment).collect();
        genes.map(|genes| Genome { genes })
    }

    /// Human-readable one-liner, e.g. `rows:3·cv | pair(cols:2) | exact`.
    pub fn describe(&self) -> String {
        self.genes
            .iter()
            .map(|g| {
                let g = g.normalized();
                if g.shape == Shape::Exact {
                    "exact".to_string()
                } else {
                    let pol = match (g.paired, g.polarity) {
                        (true, _) => "±",
                        (false, Polarity::Neg) => "-",
                        (false, Polarity::Pos) => "+",
                    };
                    let cv = if g.use_cv { "·cv" } else { "" };
                    let pair = if g.paired { "pair:" } else { "" };
                    format!("{pair}{}{pol}{}{cv}", g.shape.name(), g.m())
                }
            })
            .collect::<Vec<_>>()
            .join(" | ")
    }

    // ---- variation operators (all seeded-rng driven) -------------------

    /// A random genome. Masks are always generated as low prefixes, so
    /// random candidates are structurally valid by construction.
    pub fn random(rng: &mut Rng, n_layers: usize) -> Genome {
        let genes = (0..n_layers.max(1))
            .map(|_| match rng.below(4) {
                0 => Gene::exact(),
                k => {
                    let shape = Shape::APPROX[(k - 1) as usize];
                    let m = 1 + rng.below(MAX_M as u64) as u32;
                    let paired = rng.below(2) == 1;
                    let polarity = if !paired && rng.below(2) == 1 {
                        Polarity::Pos
                    } else {
                        Polarity::Neg
                    };
                    let use_cv = rng.below(4) != 0;
                    Gene::approx(shape, m, polarity, use_cv, paired)
                }
            })
            .collect();
        Genome { genes }
    }

    /// Uniform per-gene crossover.
    pub fn crossover(a: &Genome, b: &Genome, rng: &mut Rng) -> Genome {
        let genes = a
            .genes
            .iter()
            .zip(&b.genes)
            .map(|(&ga, &gb)| if rng.below(2) == 0 { ga } else { gb })
            .collect();
        Genome { genes }
    }

    /// Mutate 1–2 genes. Mask edits move along the prefix ladder
    /// (repair-to-prefix), so mutation can never produce a holey mask.
    pub fn mutate(&self, rng: &mut Rng) -> Genome {
        let mut genes = self.genes.clone();
        let edits = 1 + rng.below(2);
        for _ in 0..edits {
            let layer = rng.below(genes.len() as u64) as usize;
            let g = genes[layer].normalized();
            let exact = g.shape == Shape::Exact;
            genes[layer] = match rng.below(6) {
                // aggressify: drop one more position (an exact layer
                // enters the space at rows/m=1)
                0 => {
                    if exact {
                        Gene::approx(Shape::Rows, 1, Polarity::Neg, true, false)
                    } else {
                        Gene { mask: prefix_mask(g.m() + 1), ..g }
                    }
                }
                // soften: drop one fewer (m=1 collapses to exact)
                1 => {
                    if exact {
                        g
                    } else {
                        Gene { mask: prefix_mask(g.m() - 1), ..g }
                    }
                }
                // re-shape: same mask, different drop dimension
                2 => {
                    let shape = Shape::APPROX[rng.below(3) as usize];
                    if exact {
                        Gene::approx(shape, 1 + rng.below(3) as u32, Polarity::Neg, true, false)
                    } else {
                        Gene { shape, ..g }
                    }
                }
                // toggle mirrored pairing
                3 => {
                    if exact {
                        Gene::approx(Shape::Rows, 1, Polarity::Neg, true, true)
                    } else {
                        Gene { paired: !g.paired, ..g }
                    }
                }
                // flip polarity (a pair already carries both: flip CV)
                4 => {
                    if exact {
                        g
                    } else if g.paired {
                        Gene { use_cv: !g.use_cv, ..g }
                    } else {
                        let polarity = match g.polarity {
                            Polarity::Neg => Polarity::Pos,
                            Polarity::Pos => Polarity::Neg,
                        };
                        Gene { polarity, ..g }
                    }
                }
                // toggle the CV epilogue
                _ => {
                    if exact {
                        g
                    } else {
                        Gene { use_cv: !g.use_cv, ..g }
                    }
                }
            }
            .normalized();
        }
        Genome { genes }
    }

    // ---- serialization -------------------------------------------------

    pub fn to_json(&self) -> Json {
        Json::obj().field(
            "genes",
            Json::Arr(self.genes.iter().map(|g| g.to_json()).collect()),
        )
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Genome> {
        use anyhow::Context;
        let genes = j
            .get("genes")
            .and_then(|g| g.as_arr())
            .context("genome JSON missing \"genes\" array")?;
        let genes = genes
            .iter()
            .enumerate()
            .map(|(i, e)| Gene::from_json(e, i))
            .collect::<anyhow::Result<Vec<_>>>()?;
        let genome = Genome { genes };
        genome.validate()?;
        Ok(genome)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefix_masks_are_contiguous() {
        for m in 0..=MAX_M {
            assert_eq!(prefix_mask(m).count_ones(), m);
            assert_eq!(prefix_mask(m).leading_zeros() + m, 8);
        }
        assert_eq!(prefix_mask(99), prefix_mask(MAX_M));
    }

    #[test]
    fn holey_mask_is_a_typed_error() {
        let mut g = Gene::approx(Shape::Rows, 3, Polarity::Neg, true, false);
        g.mask = 0b101; // same popcount, but holey
        let err = g.validate(2).unwrap_err();
        match err {
            GenomeError::Mask { layer: 2, mask: 0b101, .. } => {}
            other => panic!("wrong error {other:?}"),
        }
        assert!(format!("{err}").contains("holey"), "{err}");
        // too-wide masks are typed too
        g.mask = 0xff;
        assert!(matches!(g.validate(0), Err(GenomeError::Mask { .. })));
        // the genome-level walk reports the offending layer
        let mut genome = Genome::exact(3);
        genome.genes[1] = Gene { mask: 0b1010, ..Gene::approx(Shape::Cols, 1, Polarity::Neg, false, false) };
        assert!(matches!(
            genome.validate(),
            Err(GenomeError::Mask { layer: 1, .. })
        ));
        assert!(matches!(Genome { genes: vec![] }.validate(), Err(GenomeError::Empty)));
    }

    #[test]
    fn normalization_collapses_exact_and_canonicalizes_pairs() {
        let z = Gene { shape: Shape::Rows, mask: 0, polarity: Polarity::Pos, use_cv: true, paired: true };
        assert_eq!(z.normalized(), Gene::exact());
        let p = Gene { shape: Shape::Cols, mask: 0b11, polarity: Polarity::Pos, use_cv: true, paired: true };
        assert_eq!(p.normalized().polarity, Polarity::Neg);
        assert!(p.normalized().paired);
    }

    #[test]
    fn assignment_roundtrip_covers_the_space() {
        for shape in Shape::APPROX {
            for m in 1..=MAX_M {
                for &paired in &[false, true] {
                    for &pol in &[Polarity::Neg, Polarity::Pos] {
                        let g = Gene::approx(shape, m, pol, true, paired);
                        let back = Gene::from_assignment(g.to_assignment()).unwrap();
                        assert_eq!(back, g, "{shape:?} m={m} paired={paired}");
                    }
                }
            }
        }
        assert_eq!(
            Gene::from_assignment(Gene::exact().to_assignment()).unwrap(),
            Gene::exact()
        );
        // A non-mirrored pairing is inexpressible — and says so.
        let odd = PairedPoint::new(
            LayerPoint::new_pol(Family::Perforated, 2, Polarity::Neg, true),
            LayerPoint::new_pol(Family::Truncated, 2, Polarity::Pos, true),
        );
        assert_eq!(Gene::from_assignment(LayerAssignment::Paired(odd)), None);
    }

    #[test]
    fn structural_check_accepts_every_prefix_gene() {
        for shape in Shape::APPROX {
            for m in 1..=MAX_M {
                let genome = Genome::uniform(
                    Gene::approx(shape, m, Polarity::Neg, true, m % 2 == 0),
                    2,
                );
                genome.structural_check().unwrap_or_else(|e| {
                    panic!("{shape:?} m={m}: {e}");
                });
            }
        }
    }

    #[test]
    fn hash_is_stable_and_normal_form_insensitive() {
        let a = Genome::uniform(Gene::approx(Shape::Rows, 2, Polarity::Neg, true, false), 3);
        assert_eq!(a.hash(), a.clone().hash());
        // a denormalized zero-mask gene hashes like the exact gene
        let mut b = a.clone();
        b.genes[0] = Gene { shape: Shape::Cols, mask: 0, polarity: Polarity::Pos, use_cv: true, paired: true };
        let mut c = a.clone();
        c.genes[0] = Gene::exact();
        assert_eq!(b.hash(), c.hash());
        assert_ne!(a.hash(), c.hash());
        // length participates (padding is not free)
        assert_ne!(Genome::exact(2).hash(), Genome::exact(3).hash());
    }

    #[test]
    fn json_roundtrip() {
        let mut genome = Genome::exact(3);
        genome.genes[0] = Gene::approx(Shape::Rows, 3, Polarity::Neg, true, false);
        genome.genes[2] = Gene::approx(Shape::SubArray, 2, Polarity::Pos, true, false);
        genome.genes[1] = Gene::approx(Shape::Cols, 1, Polarity::Neg, true, true);
        let back = Genome::from_json(&Json::parse(&genome.to_json().render()).unwrap()).unwrap();
        assert_eq!(back, genome);
        assert_eq!(back.hash(), genome.hash());
        // holey masks in an artifact are rejected on load (typed, not a panic)
        let bad = r#"{"genes": [{"shape": "rows", "mask": 5}]}"#;
        let err = Genome::from_json(&Json::parse(bad).unwrap()).unwrap_err();
        assert!(format!("{err:#}").contains("holey"), "{err:#}");
    }

    #[test]
    fn variation_operators_stay_structurally_valid() {
        let mut rng = Rng::new(7);
        let mut g = Genome::random(&mut rng, 4);
        g.validate().unwrap();
        for _ in 0..200 {
            let h = Genome::random(&mut rng, 4);
            let x = Genome::crossover(&g, &h, &mut rng);
            g = x.mutate(&mut rng);
            g.validate().unwrap();
            g.structural_check().unwrap();
        }
    }

    #[test]
    fn policy_roundtrip_through_genome() {
        let mut genome = Genome::exact(2);
        genome.genes[0] = Gene::approx(Shape::Rows, 3, Polarity::Neg, true, false);
        genome.genes[1] = Gene::approx(Shape::Rows, 1, Polarity::Neg, true, true);
        let policy = genome.to_policy().unwrap();
        assert_eq!(policy.approx_layers(), 2);
        assert_eq!(policy.paired_layers(), 1);
        assert_eq!(Genome::from_policy(&policy).unwrap(), genome);
    }
}
