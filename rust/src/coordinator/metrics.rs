//! Service metrics: latency/throughput counters + the modeled-energy bridge
//! from the hw cost model to per-inference numbers.

use std::sync::Mutex;
use std::time::Duration;

use crate::approx::Family;
use crate::hw::array_cost;
use crate::util::stats::Welford;

/// Converts inference work (MACs) into modeled energy, using the hw cost
/// model for the configured array design point.
///
/// Energy accounting: the array processes one MAC per unit cell per cycle at
/// a fixed clock (iso-delay), so energy/inference ∝ power_norm × MACs; we
/// report energy *normalized to the exact design* — the paper's quantity.
#[derive(Clone, Debug)]
pub struct PowerModel {
    pub family: Family,
    pub m: u32,
    pub n_array: u32,
    /// Power of this design normalized to the exact array.
    pub power_norm: f64,
}

impl PowerModel {
    pub fn new(family: Family, m: u32, n_array: u32) -> PowerModel {
        let power_norm = array_cost(family, m, n_array).power_norm;
        PowerModel { family, m, n_array, power_norm }
    }

    /// Modeled energy for `macs` MACs, in exact-design MAC-energy units.
    pub fn energy_units(&self, macs: u64) -> f64 {
        self.power_norm * macs as f64
    }
}

/// Aggregated service metrics (interior mutability; shared by workers).
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    completed: u64,
    batches: u64,
    latency_us: Welford,
    queue_us: Welford,
    macs: u64,
    energy_units: f64,
    energy_units_exact: f64,
    started: Option<std::time::Instant>,
    finished: Option<std::time::Instant>,
}

/// Point-in-time copy for reporting.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub completed: u64,
    pub batches: u64,
    pub mean_latency: Duration,
    pub p95_latency: Duration,
    pub mean_queue: Duration,
    pub throughput_rps: f64,
    pub total_macs: u64,
    /// Modeled energy normalized to running the same work on the exact array.
    pub energy_vs_exact: f64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record(
        &self,
        latency: Duration,
        queue_wait: Duration,
        macs: u64,
        power: &PowerModel,
    ) {
        let mut g = self.inner.lock().unwrap();
        g.completed += 1;
        g.latency_us.push(latency.as_secs_f64() * 1e6);
        g.queue_us.push(queue_wait.as_secs_f64() * 1e6);
        g.macs += macs;
        g.energy_units += power.energy_units(macs);
        g.energy_units_exact += macs as f64;
        let now = std::time::Instant::now();
        if g.started.is_none() {
            g.started = Some(now);
        }
        g.finished = Some(now);
    }

    pub fn record_batch(&self) {
        self.inner.lock().unwrap().batches += 1;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        let wall = match (g.started, g.finished) {
            (Some(s), Some(f)) if f > s => (f - s).as_secs_f64(),
            _ => 0.0,
        };
        MetricsSnapshot {
            completed: g.completed,
            batches: g.batches,
            mean_latency: Duration::from_secs_f64(g.latency_us.mean() / 1e6),
            // Welford has no p95; approximate with mean + 1.64σ (reported as such)
            p95_latency: Duration::from_secs_f64(
                (g.latency_us.mean() + 1.64 * g.latency_us.std()).max(0.0) / 1e6,
            ),
            mean_queue: Duration::from_secs_f64(g.queue_us.mean() / 1e6),
            throughput_rps: if wall > 0.0 { g.completed as f64 / wall } else { 0.0 },
            total_macs: g.macs,
            energy_vs_exact: if g.energy_units_exact > 0.0 {
                g.energy_units / g.energy_units_exact
            } else {
                1.0
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn power_model_tracks_cost_model() {
        let exact = PowerModel::new(Family::Exact, 0, 64);
        assert!((exact.power_norm - 1.0).abs() < 1e-12);
        let perf = PowerModel::new(Family::Perforated, 3, 64);
        assert!(perf.power_norm < 0.65); // ~45% reduction
        assert!(perf.energy_units(1000) < exact.energy_units(1000));
    }

    #[test]
    fn metrics_aggregate() {
        let m = Metrics::new();
        let pm = PowerModel::new(Family::Truncated, 6, 32);
        for i in 0..10 {
            m.record(
                Duration::from_micros(100 + i * 10),
                Duration::from_micros(5),
                1_000_000,
                &pm,
            );
        }
        m.record_batch();
        let s = m.snapshot();
        assert_eq!(s.completed, 10);
        assert_eq!(s.batches, 1);
        assert_eq!(s.total_macs, 10_000_000);
        assert!(s.mean_latency >= Duration::from_micros(100));
        assert!((s.energy_vs_exact - pm.power_norm).abs() < 1e-9);
    }
}
