//! Service metrics: latency/throughput counters + the modeled-energy bridge
//! from the hw cost model to per-inference numbers, plus per-worker
//! batch-size and occupancy accounting for the worker pool.

use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::approx::Family;
use crate::hw::array_cost;
use crate::nn::{LayerPolicy, Model};
use crate::util::stats::Welford;
use crate::util::sync::lock_clean;

/// Converts inference work (MACs) into modeled energy, using the hw cost
/// model for the configured array design point.
///
/// Energy accounting: the array processes one MAC per unit cell per cycle at
/// a fixed clock (iso-delay), so energy/inference ∝ power_norm × MACs; we
/// report energy *normalized to the exact design* — the paper's quantity.
#[derive(Clone, Debug)]
pub struct PowerModel {
    pub family: Family,
    pub m: u32,
    pub n_array: u32,
    /// Power of this design normalized to the exact array.
    pub power_norm: f64,
}

impl PowerModel {
    pub fn new(family: Family, m: u32, n_array: u32) -> PowerModel {
        let power_norm = array_cost(family, m, n_array).power_norm;
        PowerModel { family, m, n_array, power_norm }
    }

    /// Power model for a heterogeneous [`LayerPolicy`]: `power_norm` is the
    /// MAC-weighted mean over the layers (each at its own point's array
    /// cost, exact layers at 1.0). `family`/`m` are labeled from the
    /// policy's most aggressive approximate layer — informational only; the
    /// energy accounting uses the blended `power_norm`.
    pub fn for_policy(policy: &LayerPolicy, model: &Model, n_array: u32) -> PowerModel {
        let power_norm = policy.power_norm(model, n_array);
        let label = policy
            .points()
            .filter(|p| p.family != Family::Exact)
            .max_by_key(|p| p.m)
            .map(|p| (p.family, p.m))
            .unwrap_or((Family::Exact, 0));
        PowerModel { family: label.0, m: label.1, n_array, power_norm }
    }

    /// Modeled energy for `macs` MACs, in exact-design MAC-energy units.
    pub fn energy_units(&self, macs: u64) -> f64 {
        self.power_norm * macs as f64
    }
}

/// Number of fixed latency-histogram buckets (log₂-scale, 4 per octave of
/// microseconds: bucket `i` covers `[2^(i/4), 2^((i+1)/4))` µs). 256 buckets
/// at ~19% width span 1 µs to far beyond any plausible latency, so every
/// sample lands in a real bucket and quantiles carry ≤ ±9% bucket error.
const LAT_BUCKETS: usize = 256;
const LAT_PER_OCTAVE: f64 = 4.0;

/// Fixed-bucket log-scale latency histogram: O(1) insert, true
/// p50/p95/p99 read out of one cumulative pass — replacing the seed's
/// `mean + 1.64σ` Welford approximation, which assumed normality and
/// reported fictional "p95"s on the heavy-tailed queueing distributions a
/// bursty pool actually produces (it even went *below the mean* on
/// low-variance streams and ~40% under the true tail on bimodal ones).
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    counts: Vec<u64>,
    total: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram { counts: vec![0; LAT_BUCKETS], total: 0 }
    }
}

impl LatencyHistogram {
    pub fn new() -> LatencyHistogram {
        LatencyHistogram::default()
    }

    fn bucket_of(us: f64) -> usize {
        if us <= 1.0 {
            0
        } else {
            ((us.log2() * LAT_PER_OCTAVE) as usize).min(LAT_BUCKETS - 1)
        }
    }

    /// Geometric midpoint of bucket `i`, in microseconds.
    fn bucket_value_us(i: usize) -> f64 {
        ((i as f64 + 0.5) / LAT_PER_OCTAVE).exp2()
    }

    pub fn record(&mut self, d: Duration) {
        self.counts[Self::bucket_of(d.as_secs_f64() * 1e6)] += 1;
        self.total += 1;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    /// Read several quantiles (ascending `qs` in [0, 1]) in ONE cumulative
    /// pass over the buckets. An empty histogram reports zeros.
    pub fn quantiles(&self, qs: &[f64]) -> Vec<Duration> {
        debug_assert!(qs.windows(2).all(|w| w[0] <= w[1]), "qs must ascend");
        if self.total == 0 {
            return vec![Duration::ZERO; qs.len()];
        }
        let mut out = Vec::with_capacity(qs.len());
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            while out.len() < qs.len() && cum as f64 >= qs[out.len()] * self.total as f64 {
                out.push(Duration::from_secs_f64(Self::bucket_value_us(i) / 1e6));
            }
            if out.len() == qs.len() {
                break;
            }
        }
        while out.len() < qs.len() {
            out.push(Duration::from_secs_f64(Self::bucket_value_us(LAT_BUCKETS - 1) / 1e6));
        }
        out
    }

    pub fn quantile(&self, q: f64) -> Duration {
        self.quantiles(&[q])[0]
    }
}

/// Aggregated service metrics (interior mutability; shared by workers).
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

/// Per-worker counters for the pool (indexed by worker id).
#[derive(Clone, Debug, Default)]
struct WorkerCounters {
    batches: u64,
    requests: u64,
    busy_secs: f64,
}

/// Per-tenant-class counters (indexed by class id).
#[derive(Clone, Debug, Default)]
struct ClassCounters {
    name: String,
    completed: u64,
    lat_hist: LatencyHistogram,
    rejected_overload: u64,
    expired_deadline: u64,
}

#[derive(Debug, Default)]
struct Inner {
    completed: u64,
    batches: u64,
    latency_us: Welford,
    lat_hist: LatencyHistogram,
    queue_us: Welford,
    macs: u64,
    energy_units: f64,
    energy_units_exact: f64,
    workers: Vec<WorkerCounters>,
    classes: Vec<ClassCounters>,
    started: Option<Instant>,
    finished: Option<Instant>,
    faults: FaultCounters,
}

impl Inner {
    /// Class row for `class`, grown on demand so metrics stay usable even
    /// when `init_classes` was never called (single-tenant tests).
    fn class_mut(&mut self, class: usize) -> &mut ClassCounters {
        if self.classes.len() <= class {
            let start = self.classes.len();
            self.classes.resize(class + 1, ClassCounters::default());
            for (i, c) in self.classes.iter_mut().enumerate().skip(start) {
                if c.name.is_empty() {
                    c.name = format!("class{i}");
                }
            }
        }
        &mut self.classes[class]
    }
}

/// Robustness counters for the fault/self-healing plane.
#[derive(Clone, Copy, Debug, Default)]
struct FaultCounters {
    rejected_overload: u64,
    expired_deadline: u64,
    worker_restarts: u64,
    heal_events: u64,
    integrity_alarms: u64,
    replayed_batches: u64,
    crashed_replies: u64,
    injected_faults: u64,
}

/// Point-in-time copy for reporting.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub completed: u64,
    pub batches: u64,
    pub mean_latency: Duration,
    /// True histogram quantiles (log-bucket resolution, ≤ ±9%), not the
    /// seed's mean + 1.64σ normal-tail guess.
    pub p50_latency: Duration,
    pub p95_latency: Duration,
    pub p99_latency: Duration,
    pub mean_queue: Duration,
    pub throughput_rps: f64,
    pub total_macs: u64,
    /// Modeled energy normalized to running the same work on the exact array.
    pub energy_vs_exact: f64,
    /// Mean requests fused per batch (completed work / batches run).
    pub mean_batch_size: f64,
    /// Batches executed by each pool worker (index = worker id).
    pub worker_batches: Vec<u64>,
    /// Requests served by each pool worker.
    pub worker_requests: Vec<u64>,
    /// Fraction of the service wall-clock each worker spent inside
    /// `forward_batch` (busy / wall); 0 when no wall-clock has elapsed.
    pub worker_occupancy: Vec<f64>,
    /// Requests rejected at admission by the bounded queue.
    pub rejected_overload: u64,
    /// Requests whose deadline expired before execution (dropped at dequeue).
    pub expired_deadline: u64,
    /// Crashed workers respawned by the supervisor.
    pub worker_restarts: u64,
    /// Corrupt LUTs/plans rebuilt or invalidated by healing.
    pub heal_events: u64,
    /// CV-residual band breaches that triggered a checksum sweep.
    pub integrity_alarms: u64,
    /// Batches re-executed after an integrity breach.
    pub replayed_batches: u64,
    /// Requests answered with a typed `WorkerCrashed` error.
    pub crashed_replies: u64,
    /// Faults the injection plan actually applied.
    pub injected_faults: u64,
    /// Per-tenant-class rows (index = class id; empty when the service
    /// never declared classes and nothing was recorded per class).
    pub classes: Vec<ClassSnapshot>,
}

/// Point-in-time per-tenant-class metrics.
#[derive(Clone, Debug)]
pub struct ClassSnapshot {
    pub name: String,
    pub completed: u64,
    pub p50_latency: Duration,
    pub p95_latency: Duration,
    pub p99_latency: Duration,
    /// Throughput over the service wall-clock (same anchor as the global
    /// `throughput_rps`).
    pub throughput_rps: f64,
    pub rejected_overload: u64,
    pub expired_deadline: u64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Anchor the throughput wall-clock at service start. Without this,
    /// `record` anchors at the *first* completion, which made a session
    /// with one completed request report `throughput_rps == 0.0`.
    pub fn mark_started(&self) {
        let mut g = lock_clean(&self.inner);
        if g.started.is_none() {
            g.started = Some(Instant::now());
        }
    }

    /// Declare the pool size up front so the per-worker vectors in the
    /// snapshot cover *every* worker — idle workers report zeros instead of
    /// being silently absent (the lazy grow in `record_batch` only reaches
    /// the highest worker id that actually ran a batch).
    pub fn init_workers(&self, n: usize) {
        let mut g = lock_clean(&self.inner);
        if g.workers.len() < n {
            g.workers.resize(n, WorkerCounters::default());
        }
    }

    /// Declare the tenant classes up front so every class reports a row
    /// (idle classes appear as zeros) under its configured name.
    pub fn init_classes(&self, names: &[String]) {
        let mut g = lock_clean(&self.inner);
        if g.classes.len() < names.len() {
            g.classes.resize(names.len(), ClassCounters::default());
        }
        for (c, name) in g.classes.iter_mut().zip(names) {
            name.clone_into(&mut c.name);
        }
    }

    pub fn record(
        &self,
        latency: Duration,
        queue_wait: Duration,
        macs: u64,
        power: &PowerModel,
    ) {
        self.record_for(0, latency, queue_wait, macs, power);
    }

    /// Record one completed request of tenant class `class`.
    pub fn record_for(
        &self,
        class: usize,
        latency: Duration,
        queue_wait: Duration,
        macs: u64,
        power: &PowerModel,
    ) {
        let mut g = lock_clean(&self.inner);
        g.completed += 1;
        g.latency_us.push(latency.as_secs_f64() * 1e6);
        g.lat_hist.record(latency);
        g.queue_us.push(queue_wait.as_secs_f64() * 1e6);
        g.macs += macs;
        g.energy_units += power.energy_units(macs);
        g.energy_units_exact += macs as f64;
        let row = g.class_mut(class);
        row.completed += 1;
        row.lat_hist.record(latency);
        let now = Instant::now();
        if g.started.is_none() {
            g.started = Some(now);
        }
        g.finished = Some(now);
    }

    /// Account one executed batch to pool worker `worker`: `requests` fused
    /// into it and the time the worker spent running it.
    pub fn record_batch(&self, worker: usize, requests: usize, busy: Duration) {
        let mut g = lock_clean(&self.inner);
        g.batches += 1;
        if g.workers.len() <= worker {
            g.workers.resize(worker + 1, WorkerCounters::default());
        }
        let wc = &mut g.workers[worker];
        wc.batches += 1;
        wc.requests += requests as u64;
        wc.busy_secs += busy.as_secs_f64();
    }

    /// Count a request rejected at admission (bounded queue full).
    pub fn record_overload(&self) {
        self.record_overload_for(0);
    }

    /// Count a class-`class` request rejected at admission.
    pub fn record_overload_for(&self, class: usize) {
        let mut g = lock_clean(&self.inner);
        g.faults.rejected_overload += 1;
        g.class_mut(class).rejected_overload += 1;
    }

    /// Count a request whose deadline expired before execution.
    pub fn record_deadline_expired(&self) {
        self.record_deadline_expired_for(0);
    }

    /// Count a class-`class` request whose deadline expired before
    /// execution.
    pub fn record_deadline_expired_for(&self, class: usize) {
        let mut g = lock_clean(&self.inner);
        g.faults.expired_deadline += 1;
        g.class_mut(class).expired_deadline += 1;
    }

    /// Count a crashed worker respawned by the supervisor.
    pub fn record_worker_restart(&self) {
        lock_clean(&self.inner).faults.worker_restarts += 1;
    }

    /// Count `n` healed state objects (rebuilt LUTs + invalidated plans).
    pub fn record_heal(&self, n: usize) {
        lock_clean(&self.inner).faults.heal_events += n as u64;
    }

    /// Count a CV-residual band breach (alarm; may be a false positive —
    /// the checksum sweep arbitrates).
    pub fn record_integrity_alarm(&self) {
        lock_clean(&self.inner).faults.integrity_alarms += 1;
    }

    /// Count a batch re-executed after an integrity breach.
    pub fn record_replay(&self) {
        lock_clean(&self.inner).faults.replayed_batches += 1;
    }

    /// Count `n` requests answered with a typed `WorkerCrashed` error.
    pub fn record_crashed_replies(&self, n: usize) {
        lock_clean(&self.inner).faults.crashed_replies += n as u64;
    }

    /// Count `n` faults the injection plan actually applied.
    pub fn record_injected_faults(&self, n: usize) {
        lock_clean(&self.inner).faults.injected_faults += n as u64;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = lock_clean(&self.inner);
        let wall = match (g.started, g.finished) {
            (Some(s), Some(f)) if f > s => (f - s).as_secs_f64(),
            _ => 0.0,
        };
        let quantiles = g.lat_hist.quantiles(&[0.50, 0.95, 0.99]);
        MetricsSnapshot {
            completed: g.completed,
            batches: g.batches,
            mean_latency: Duration::from_secs_f64(g.latency_us.mean() / 1e6),
            p50_latency: quantiles[0],
            p95_latency: quantiles[1],
            p99_latency: quantiles[2],
            mean_queue: Duration::from_secs_f64(g.queue_us.mean() / 1e6),
            throughput_rps: if wall > 0.0 { g.completed as f64 / wall } else { 0.0 },
            total_macs: g.macs,
            energy_vs_exact: if g.energy_units_exact > 0.0 {
                g.energy_units / g.energy_units_exact
            } else {
                1.0
            },
            mean_batch_size: if g.batches > 0 {
                g.workers.iter().map(|w| w.requests).sum::<u64>() as f64
                    / g.batches as f64
            } else {
                0.0
            },
            worker_batches: g.workers.iter().map(|w| w.batches).collect(),
            worker_requests: g.workers.iter().map(|w| w.requests).collect(),
            worker_occupancy: g
                .workers
                .iter()
                .map(|w| if wall > 0.0 { w.busy_secs / wall } else { 0.0 })
                .collect(),
            rejected_overload: g.faults.rejected_overload,
            expired_deadline: g.faults.expired_deadline,
            worker_restarts: g.faults.worker_restarts,
            heal_events: g.faults.heal_events,
            integrity_alarms: g.faults.integrity_alarms,
            replayed_batches: g.faults.replayed_batches,
            crashed_replies: g.faults.crashed_replies,
            injected_faults: g.faults.injected_faults,
            classes: g
                .classes
                .iter()
                .map(|c| {
                    let q = c.lat_hist.quantiles(&[0.50, 0.95, 0.99]);
                    ClassSnapshot {
                        name: c.name.clone(),
                        completed: c.completed,
                        p50_latency: q[0],
                        p95_latency: q[1],
                        p99_latency: q[2],
                        throughput_rps: if wall > 0.0 {
                            c.completed as f64 / wall
                        } else {
                            0.0
                        },
                        rejected_overload: c.rejected_overload,
                        expired_deadline: c.expired_deadline,
                    }
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn power_model_tracks_cost_model() {
        let exact = PowerModel::new(Family::Exact, 0, 64);
        assert!((exact.power_norm - 1.0).abs() < 1e-12);
        let perf = PowerModel::new(Family::Perforated, 3, 64);
        assert!(perf.power_norm < 0.65); // ~45% reduction
        assert!(perf.energy_units(1000) < exact.energy_units(1000));
    }

    #[test]
    fn policy_power_model_blends_mac_weighted() {
        let model = crate::nn::testutil::tiny_model();
        let macs = model.mac_layer_macs();
        // All-exact policy: power 1.0.
        let exact = LayerPolicy::uniform(Family::Exact, 0, false, 2).unwrap();
        let pm = PowerModel::for_policy(&exact, &model, 64);
        assert!((pm.power_norm - 1.0).abs() < 1e-12);
        assert_eq!((pm.family, pm.m), (Family::Exact, 0));
        // Uniform policy matches the uniform constructor.
        let uni = LayerPolicy::uniform(Family::Perforated, 3, true, 2).unwrap();
        let pm_uni = PowerModel::for_policy(&uni, &model, 64);
        let direct = PowerModel::new(Family::Perforated, 3, 64);
        assert!((pm_uni.power_norm - direct.power_norm).abs() < 1e-12);
        assert_eq!((pm_uni.family, pm_uni.m), (Family::Perforated, 3));
        // Mixed: exactly the hand-computed MAC-weighted blend.
        let mixed = LayerPolicy::from_ms(Family::Perforated, &[3, 0], true).unwrap();
        let pm_mixed = PowerModel::for_policy(&mixed, &model, 64);
        let total = (macs[0] + macs[1]) as f64;
        let want =
            (macs[0] as f64 * direct.power_norm + macs[1] as f64) / total;
        assert!((pm_mixed.power_norm - want).abs() < 1e-12);
        assert!(pm_mixed.power_norm > direct.power_norm);
        assert!(pm_mixed.power_norm < 1.0);
    }

    #[test]
    fn histogram_quantiles_track_true_percentiles() {
        // 1000 uniform samples 1..=1000 ms: true p50/p95/p99 are
        // 500/950/990 ms; the log-bucket histogram must land within one
        // bucket (±9%) of each, in one pass, in order.
        let mut h = LatencyHistogram::new();
        for ms in 1..=1000u64 {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 1000);
        let q = h.quantiles(&[0.50, 0.95, 0.99]);
        let want = [500.0, 950.0, 990.0];
        for (got, want) in q.iter().zip(want) {
            let got_ms = got.as_secs_f64() * 1e3;
            assert!(
                (got_ms / want - 1.0).abs() < 0.12,
                "histogram quantile {got_ms} ms vs true {want} ms"
            );
        }
        assert!(q[0] <= q[1] && q[1] <= q[2]);
        // The Welford approximation this replaces would have reported
        // mean + 1.64σ ≈ 974 ms as "p95" AND as the only tail number —
        // with no p50/p99 at all.
        assert_eq!(h.quantile(0.95), q[1]);
    }

    #[test]
    fn histogram_beats_normal_approximation_on_bimodal_load() {
        // A bimodal latency mix (90% fast at 1 ms, 10% queued at 100 ms) is
        // exactly what a bursty pool produces. True p95 = 100 ms; the old
        // mean + 1.64σ formula says ~59 ms — off by ~40%. The histogram
        // must stay within bucket resolution of the truth.
        let mut h = LatencyHistogram::new();
        let mut w = Welford::new();
        for i in 0..1000u64 {
            let ms = if i % 10 == 9 { 100 } else { 1 };
            h.record(Duration::from_millis(ms));
            w.push(ms as f64 * 1e3);
        }
        let p95 = h.quantile(0.95).as_secs_f64() * 1e3;
        assert!((p95 / 100.0 - 1.0).abs() < 0.12, "true-tail p95 {p95} ms");
        let fake = (w.mean() + 1.64 * w.std()) / 1e3;
        assert!(
            fake < 70.0,
            "premise: the normal approximation underestimates ({fake} ms)"
        );
    }

    #[test]
    fn histogram_edge_cases() {
        let h = LatencyHistogram::new();
        assert_eq!(h.quantile(0.95), Duration::ZERO, "empty histogram");
        let mut h = LatencyHistogram::new();
        h.record(Duration::ZERO); // sub-µs lands in bucket 0
        h.record(Duration::from_secs(1_000_000)); // absurd tail is clamped
        let q = h.quantiles(&[0.25, 0.99]);
        assert!(q[0] <= Duration::from_micros(2));
        assert!(q[1] >= Duration::from_secs(1000));
    }

    #[test]
    fn metrics_aggregate() {
        let m = Metrics::new();
        let pm = PowerModel::new(Family::Truncated, 6, 32);
        for i in 0..10 {
            m.record(
                Duration::from_micros(100 + i * 10),
                Duration::from_micros(5),
                1_000_000,
                &pm,
            );
        }
        m.record_batch(0, 10, Duration::from_micros(800));
        let s = m.snapshot();
        assert_eq!(s.completed, 10);
        assert_eq!(s.batches, 1);
        assert_eq!(s.total_macs, 10_000_000);
        assert!(s.mean_latency >= Duration::from_micros(100));
        assert!((s.energy_vs_exact - pm.power_norm).abs() < 1e-9);
        assert_eq!(s.worker_batches, vec![1]);
        assert_eq!(s.worker_requests, vec![10]);
        assert!((s.mean_batch_size - 10.0).abs() < 1e-12);
    }

    #[test]
    fn single_request_reports_nonzero_throughput() {
        // Regression: wall-clock used to span first..last record, so one
        // completed request meant wall == 0 and throughput_rps == 0.
        let m = Metrics::new();
        m.mark_started();
        std::thread::sleep(Duration::from_millis(2));
        let pm = PowerModel::new(Family::Exact, 0, 64);
        m.record(Duration::from_micros(50), Duration::ZERO, 1000, &pm);
        let s = m.snapshot();
        assert_eq!(s.completed, 1);
        assert!(
            s.throughput_rps > 0.0,
            "single completed request must report nonzero throughput"
        );
        // And the rate is measured against service start, not the record
        // instant: ≥2 ms wall means ≤500 rps here.
        assert!(s.throughput_rps <= 500.0, "rps {}", s.throughput_rps);
    }

    #[test]
    fn init_workers_reports_idle_workers_as_zeros() {
        let m = Metrics::new();
        m.init_workers(3);
        m.record_batch(1, 2, Duration::from_micros(10));
        let s = m.snapshot();
        assert_eq!(s.worker_batches, vec![0, 1, 0]);
        assert_eq!(s.worker_requests, vec![0, 2, 0]);
        assert_eq!(s.worker_occupancy.len(), 3);
    }

    #[test]
    fn fault_counters_flow_into_snapshot() {
        let m = Metrics::new();
        m.record_overload();
        m.record_overload();
        m.record_deadline_expired();
        m.record_worker_restart();
        m.record_heal(3);
        m.record_integrity_alarm();
        m.record_replay();
        m.record_crashed_replies(4);
        m.record_injected_faults(2);
        let s = m.snapshot();
        assert_eq!(s.rejected_overload, 2);
        assert_eq!(s.expired_deadline, 1);
        assert_eq!(s.worker_restarts, 1);
        assert_eq!(s.heal_events, 3);
        assert_eq!(s.integrity_alarms, 1);
        assert_eq!(s.replayed_batches, 1);
        assert_eq!(s.crashed_replies, 4);
        assert_eq!(s.injected_faults, 2);
        // A fresh snapshot starts all-zero.
        let z = Metrics::new().snapshot();
        assert_eq!(z.rejected_overload + z.heal_events + z.worker_restarts, 0);
    }

    #[test]
    fn per_class_counters_partition_the_snapshot() {
        let m = Metrics::new();
        m.init_classes(&["interactive".into(), "batchy".into()]);
        let pm = PowerModel::new(Family::Exact, 0, 64);
        m.mark_started();
        std::thread::sleep(Duration::from_millis(1));
        m.record_for(0, Duration::from_millis(1), Duration::ZERO, 100, &pm);
        m.record_for(0, Duration::from_millis(2), Duration::ZERO, 100, &pm);
        m.record_for(1, Duration::from_millis(50), Duration::ZERO, 100, &pm);
        m.record_overload_for(1);
        m.record_deadline_expired_for(0);
        let s = m.snapshot();
        assert_eq!(s.completed, 3, "global view spans all classes");
        assert_eq!(s.rejected_overload, 1);
        assert_eq!(s.expired_deadline, 1);
        assert_eq!(s.classes.len(), 2);
        assert_eq!(s.classes[0].name, "interactive");
        assert_eq!(s.classes[0].completed, 2);
        assert_eq!(s.classes[0].expired_deadline, 1);
        assert_eq!(s.classes[0].rejected_overload, 0);
        assert_eq!(s.classes[1].name, "batchy");
        assert_eq!(s.classes[1].completed, 1);
        assert_eq!(s.classes[1].rejected_overload, 1);
        // Tails are per class: the batchy class's p99 reflects its own
        // 50 ms sample, not the interactive class's.
        assert!(s.classes[1].p99_latency >= Duration::from_millis(40));
        assert!(s.classes[0].p99_latency <= Duration::from_millis(5));
        assert!(s.classes[0].throughput_rps > s.classes[1].throughput_rps);
        // Recording to an undeclared class grows a named placeholder row.
        m.record_deadline_expired_for(3);
        let s2 = m.snapshot();
        assert_eq!(s2.classes.len(), 4);
        assert_eq!(s2.classes[3].name, "class3");
        assert_eq!(s2.classes[2].completed, 0);
    }

    #[test]
    fn per_worker_counters_accumulate_independently() {
        let m = Metrics::new();
        m.record_batch(1, 3, Duration::from_micros(30));
        m.record_batch(1, 5, Duration::from_micros(50));
        m.record_batch(3, 2, Duration::from_micros(20));
        let s = m.snapshot();
        assert_eq!(s.batches, 3);
        assert_eq!(s.worker_batches, vec![0, 2, 0, 1]);
        assert_eq!(s.worker_requests, vec![0, 8, 0, 2]);
        assert!((s.mean_batch_size - 10.0 / 3.0).abs() < 1e-12);
        // No wall-clock elapsed (no record/mark_started): occupancy is 0.
        assert!(s.worker_occupancy.iter().all(|&o| o == 0.0));
    }
}
