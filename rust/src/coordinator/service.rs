//! The inference service: request queue → dynamic batcher → worker loop.
//!
//! std-threads + channels (no tokio in the offline vendor set). Requests are
//! submitted from any thread; a worker drains the queue into batches of up
//! to `batch_size` (batching amortizes dispatch overhead — and on the PJRT
//! path, executable-call overhead), runs the engine, and answers each
//! request through its own oneshot channel.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, Sender, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::metrics::{Metrics, MetricsSnapshot, PowerModel};
use crate::approx::Family;
use crate::nn::{Engine, ForwardOpts, Scratch, Tensor};

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub family: Family,
    pub m: u32,
    pub use_cv: bool,
    /// Simulated MAC array dimension (for the power model).
    pub n_array: u32,
    /// Max requests fused into one worker batch.
    pub batch_size: usize,
    /// How long the batcher waits to fill a batch before running a partial
    /// one.
    pub batch_timeout: Duration,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            family: Family::Exact,
            m: 0,
            use_cv: false,
            n_array: 64,
            batch_size: 8,
            batch_timeout: Duration::from_millis(2),
        }
    }
}

/// One classification result.
#[derive(Clone, Debug)]
pub struct Reply {
    pub logits: Vec<f64>,
    pub top1: usize,
    pub latency: Duration,
}

struct Request {
    image: Tensor,
    enqueued: Instant,
    respond: SyncSender<Result<Reply, String>>,
}

/// Handle for a submitted request.
pub struct Pending {
    rx: Receiver<Result<Reply, String>>,
}

impl Pending {
    /// Block until the reply arrives.
    pub fn wait(self) -> Result<Reply> {
        self.rx
            .recv()
            .context("service dropped the request")?
            .map_err(|e| anyhow::anyhow!(e))
    }
}

/// A running inference service (worker thread + queue).
pub struct InferenceService {
    tx: Option<Sender<Request>>,
    worker: Option<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    pub power: PowerModel,
    stop: Arc<AtomicBool>,
}

impl InferenceService {
    /// Start the service over a prepared engine.
    pub fn start(engine: Engine, cfg: ServiceConfig) -> InferenceService {
        let metrics = Arc::new(Metrics::new());
        let power = PowerModel::new(cfg.family, cfg.m, cfg.n_array);
        let stop = Arc::new(AtomicBool::new(false));
        let (tx, rx) = mpsc::channel::<Request>();
        let worker = {
            let metrics = metrics.clone();
            let power = power.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                worker_loop(engine, cfg, rx, metrics, power, stop);
            })
        };
        InferenceService { tx: Some(tx), worker: Some(worker), metrics, power, stop }
    }

    /// Submit an image; returns a handle to wait on.
    pub fn submit(&self, image: Tensor) -> Pending {
        let (rtx, rrx) = mpsc::sync_channel(1);
        let req = Request { image, enqueued: Instant::now(), respond: rtx };
        self.tx
            .as_ref()
            .expect("service running")
            .send(req)
            .expect("worker alive");
        Pending { rx: rrx }
    }

    /// Submit and wait (convenience).
    pub fn infer(&self, image: Tensor) -> Result<Reply> {
        self.submit(image).wait()
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Drain and stop the worker.
    pub fn shutdown(mut self) -> MetricsSnapshot {
        self.stop.store(true, Ordering::SeqCst);
        drop(self.tx.take());
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
        self.metrics.snapshot()
    }
}

impl Drop for InferenceService {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        drop(self.tx.take());
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

fn worker_loop(
    engine: Engine,
    cfg: ServiceConfig,
    rx: Receiver<Request>,
    metrics: Arc<Metrics>,
    power: PowerModel,
    stop: Arc<AtomicBool>,
) {
    let opts = ForwardOpts::approx(cfg.family, cfg.m, cfg.use_cv);
    let macs = engine.model.macs();
    // Warm the weight-side layer plans before serving so the first request
    // does not pay the one-time build, and keep a single scratch arena for
    // the worker's whole lifetime: plans survive across batches (the cache
    // sits on the engine) and steady-state forwards allocate nothing.
    engine.prepare_plans(cfg.family, cfg.m);
    let mut scratch = Scratch::new();
    let (panel, acc) = engine.model.max_gemm_footprint();
    scratch.reserve(panel, acc);
    loop {
        // Block for the first request of a batch.
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => break, // all senders dropped
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + cfg.batch_timeout;
        while batch.len() < cfg.batch_size {
            let left = deadline.saturating_duration_since(Instant::now());
            match rx.recv_timeout(left) {
                Ok(r) => batch.push(r),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        metrics.record_batch();
        for req in batch {
            let queue_wait = req.enqueued.elapsed();
            let t0 = Instant::now();
            let result = engine
                .forward_with_scratch(&req.image, &opts, &mut scratch)
                .map(|logits| {
                    let top1 = argmax(&logits);
                    Reply { logits, top1, latency: t0.elapsed() }
                })
                .map_err(|e| e.to_string());
            let latency = req.enqueued.elapsed();
            metrics.record(latency, queue_wait, macs, &power);
            let _ = req.respond.send(result);
        }
        if stop.load(Ordering::SeqCst) {
            // drain whatever is left, then exit
            while let Ok(req) = rx.try_recv() {
                let _ = req.respond.send(Err("service shutting down".into()));
            }
            break;
        }
    }
}

pub fn argmax(xs: &[f64]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artifacts_dir;
    use crate::nn::loader;

    fn engine() -> Option<Engine> {
        let path = artifacts_dir().join("models/mininet_synth10.cvm");
        if !path.exists() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        Some(Engine::new(loader::load_model(&path).unwrap()))
    }

    #[test]
    fn serves_requests_and_counts_metrics() {
        let Some(engine) = engine() else { return };
        let ds = crate::datasets::Dataset::load(
            &artifacts_dir().join("data/synth10_test.cvd"),
        )
        .unwrap();
        let cfg = ServiceConfig {
            family: Family::Perforated,
            m: 2,
            use_cv: true,
            batch_size: 4,
            ..Default::default()
        };
        let svc = InferenceService::start(engine, cfg);
        let pendings: Vec<Pending> =
            (0..8).map(|i| svc.submit(ds.image(i))).collect();
        let mut correct = 0;
        for (i, p) in pendings.into_iter().enumerate() {
            let reply = p.wait().unwrap();
            assert_eq!(reply.logits.len(), 10);
            if reply.top1 == ds.label(i) {
                correct += 1;
            }
        }
        let snap = svc.shutdown();
        assert_eq!(snap.completed, 8);
        assert!(snap.batches >= 1 && snap.batches <= 8);
        assert!(snap.total_macs > 0);
        assert!(snap.energy_vs_exact < 1.0); // approximate design saves power
        assert!(correct >= 4, "sanity: {correct}/8 correct");
    }

    #[test]
    fn shutdown_is_clean_with_no_requests() {
        let Some(engine) = engine() else { return };
        let svc = InferenceService::start(engine, ServiceConfig::default());
        let snap = svc.shutdown();
        assert_eq!(snap.completed, 0);
    }

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[1.0, 5.0, 3.0]), 1);
        assert_eq!(argmax(&[]), 0);
    }
}
