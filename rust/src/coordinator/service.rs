//! The inference service: request queue → dynamic batcher → worker pool.
//!
//! std-threads + a Mutex/Condvar queue (no tokio in the offline vendor
//! set). Requests are submitted from any thread; each pool worker drains
//! the shared queue into batches of up to `batch_size`, fuses the batch
//! through [`Engine::forward_batch_with_scratch`] — **one wide GEMM per
//! layer**, the weight-side plan amortized over every image — and answers
//! each request through its own oneshot channel.
//!
//! Hardening invariants (tested below):
//! * NaN logits never panic a worker: [`argmax`] ranks NaN below every real
//!   value, and an all-NaN output answers the request with `Err` instead of
//!   a garbage class.
//! * `submit`/`infer` return `Err` after shutdown/close or when the pool
//!   has no live workers — they never panic the caller.
//! * A malformed (wrong-shape) image fails alone; it is split out before
//!   the batch is fused so neighbors still get answers.
//! * A bad per-layer policy (`ServiceConfig::policy` /
//!   `CVAPPROX_SERVICE_POLICY`) fails at `start` — before any worker
//!   spawns — so it can never poison a live pool.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, SyncSender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::metrics::{Metrics, MetricsSnapshot, PowerModel};
use crate::approx::Family;
use crate::nn::{
    Engine, ForwardOpts, LayerPolicy, Model, PolicySwitch, Scratch, SharedPolicy,
    StampedPolicy, Tensor,
};
use crate::qos::Telemetry;
use crate::util::threadpool::default_workers;

/// Worker-pool size: `CVAPPROX_SERVICE_WORKERS` when set to a positive
/// integer (the CI serving smoke pins 1 and 4), else
/// `available_parallelism / CVAPPROX_THREADS` — pool workers and intra-GEMM
/// threads multiply, so the default divides the cores between the two
/// levels instead of oversubscribing quadratically (16 cores with the
/// default GEMM threading would otherwise run up to 256 runnable threads).
/// Read per service start (not cached) so tests and harnesses can vary it.
pub fn default_service_workers() -> usize {
    std::env::var("CVAPPROX_SERVICE_WORKERS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&v| v >= 1)
        .unwrap_or_else(|| {
            (default_workers() / crate::util::threadpool::configured_workers()).max(1)
        })
        .clamp(1, 256)
}

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub family: Family,
    pub m: u32,
    pub use_cv: bool,
    /// Per-layer heterogeneous policy. When set it supersedes the uniform
    /// `family`/`m`/`use_cv` triple: every worker serves mixed-m batches,
    /// each layer at its policy point, sharing one plan cache. When unset,
    /// `InferenceService::start` also consults `CVAPPROX_SERVICE_POLICY`
    /// (path to a JSON/text policy file — see `nn::policy`).
    pub policy: Option<SharedPolicy>,
    /// Simulated MAC array dimension (for the power model).
    pub n_array: u32,
    /// Pool workers sharing one engine (plans/LUT) with one scratch each.
    pub workers: usize,
    /// Max requests fused into one worker batch (one wide GEMM per layer).
    pub batch_size: usize,
    /// How long the batcher waits to fill a batch before running a partial
    /// one.
    pub batch_timeout: Duration,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            family: Family::Exact,
            m: 0,
            use_cv: false,
            policy: None,
            n_array: 64,
            workers: default_service_workers(),
            batch_size: 8,
            batch_timeout: Duration::from_millis(2),
        }
    }
}

/// Resolve the effective policy for a service: an explicit
/// `ServiceConfig::policy` wins; otherwise `env_path` (the value of
/// `CVAPPROX_SERVICE_POLICY`) names a policy file to load. Factored out of
/// `start` so the file/parse error paths are unit-testable without touching
/// process-global env state.
fn resolve_policy(
    explicit: Option<&SharedPolicy>,
    env_path: Option<&str>,
) -> Result<Option<SharedPolicy>> {
    if let Some(p) = explicit {
        return Ok(Some(p.clone()));
    }
    match env_path.map(str::trim) {
        Some(path) if !path.is_empty() => {
            let policy = LayerPolicy::load(std::path::Path::new(path))
                .context("CVAPPROX_SERVICE_POLICY")?;
            Ok(Some(std::sync::Arc::new(policy)))
        }
        _ => Ok(None),
    }
}

/// One classification result.
#[derive(Clone, Debug)]
pub struct Reply {
    pub logits: Vec<f64>,
    pub top1: usize,
    pub latency: Duration,
    /// Policy generation that served this request (see
    /// [`crate::nn::PolicySwitch`]): the whole batch this request was fused
    /// into ran under exactly this epoch's policy, so the reply is
    /// bit-identical to a static forward under that generation — the
    /// hot-swap consistency anchor (property-tested below).
    pub epoch: u64,
}

struct Request {
    image: Tensor,
    enqueued: Instant,
    respond: SyncSender<Result<Reply, String>>,
}

/// Handle for a submitted request.
pub struct Pending {
    rx: Receiver<Result<Reply, String>>,
}

impl Pending {
    /// Block until the reply arrives.
    pub fn wait(self) -> Result<Reply> {
        self.rx
            .recv()
            .context("service dropped the request")?
            .map_err(|e| anyhow::anyhow!(e))
    }
}

/// MPMC request queue feeding the worker pool: a Mutex'd VecDeque plus a
/// Condvar, with the dynamic-batching wait built into [`SharedQueue::pop_batch`].
struct SharedQueue {
    inner: Mutex<QueueInner>,
    cv: Condvar,
}

#[derive(Default)]
struct QueueInner {
    queue: VecDeque<Request>,
    closed: bool,
}

impl SharedQueue {
    fn new() -> SharedQueue {
        SharedQueue { inner: Mutex::new(QueueInner::default()), cv: Condvar::new() }
    }

    /// Enqueue unless the service was closed; hands the request back on
    /// rejection so the caller can answer it. (Checked under the same lock
    /// as `close`, so no request can slip in after the drain decision.)
    fn push(&self, req: Request) -> std::result::Result<(), Request> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(req);
        }
        g.queue.push_back(req);
        drop(g);
        self.cv.notify_one();
        Ok(())
    }

    /// Stop accepting; queued work still drains. Wakes every worker so
    /// idle ones can exit.
    fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Current queue depth (governor telemetry; racy by nature).
    fn len(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    /// Answer every still-queued request with `Err(msg)` — used when the
    /// last worker dies with work left in the queue.
    fn drain_reject(&self, msg: &str) {
        let drained: Vec<Request> = {
            let mut g = self.inner.lock().unwrap();
            g.queue.drain(..).collect()
        };
        for req in drained {
            let _ = req.respond.send(Err(msg.to_string()));
        }
    }

    /// Dynamic batcher: block for the first request (`None` once closed
    /// *and* drained — the worker-exit signal), then wait up to `timeout`
    /// for the batch to fill to `max`. Also returns the queue depth left
    /// behind (read under the same lock — the telemetry gauge costs no
    /// extra acquisition on the hot path).
    fn pop_batch(&self, max: usize, timeout: Duration) -> Option<(Vec<Request>, usize)> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if !g.queue.is_empty() {
                break;
            }
            if g.closed {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
        let mut batch = Vec::with_capacity(max);
        while batch.len() < max {
            match g.queue.pop_front() {
                Some(r) => batch.push(r),
                None => break,
            }
        }
        if batch.len() < max && !g.closed {
            let deadline = Instant::now() + timeout;
            loop {
                let left = deadline.saturating_duration_since(Instant::now());
                if left.is_zero() {
                    break;
                }
                let (g2, wres) = self.cv.wait_timeout(g, left).unwrap();
                g = g2;
                while batch.len() < max {
                    match g.queue.pop_front() {
                        Some(r) => batch.push(r),
                        None => break,
                    }
                }
                if batch.len() >= max || g.closed || wres.timed_out() {
                    break;
                }
            }
        }
        let depth = g.queue.len();
        Some((batch, depth))
    }
}

/// Decrements the live-worker count on scope exit — including a panic
/// unwind — so `submit` can report a dead pool instead of hanging callers.
/// When the *last* worker exits it also closes the queue and rejects any
/// requests still waiting in it: with nobody left to pop them, their reply
/// channels would otherwise stay open and `Pending::wait` would block
/// forever. (On graceful shutdown the queue is already closed and drained
/// by the time the last worker exits, so this is a no-op there.)
struct AliveGuard {
    alive: Arc<AtomicUsize>,
    queue: Arc<SharedQueue>,
}

impl Drop for AliveGuard {
    fn drop(&mut self) {
        if self.alive.fetch_sub(1, Ordering::SeqCst) == 1 {
            self.queue.close();
            self.queue.drain_reject("inference service has no live workers");
        }
    }
}

/// Everything a pool worker shares with its siblings (one `Arc` bundle per
/// worker instead of a parameter per handle). The policy half is the
/// hot-swap surface: `switch` is loaded once per batch, `powers` maps each
/// installed epoch to its precomputed [`PowerModel`] so energy accounting
/// follows the rung that actually served the batch.
#[derive(Clone)]
struct WorkerShared {
    engine: Arc<Engine>,
    queue: Arc<SharedQueue>,
    metrics: Arc<Metrics>,
    telemetry: Arc<Telemetry>,
    switch: Arc<PolicySwitch>,
    powers: Arc<Mutex<HashMap<u64, PowerModel>>>,
    /// Uniform fallback for generations installed with `policy == None`.
    base_opts: ForwardOpts,
    base_power: PowerModel,
    alive: Arc<AtomicUsize>,
}

impl WorkerShared {
    /// Resolve the forward configuration for one batch from a captured
    /// generation. The CV-proxy sampler is attached here so every batch
    /// feeds the shared telemetry regardless of rung.
    fn resolve_opts(&self, stamped: &StampedPolicy) -> ForwardOpts {
        let mut opts = match &stamped.policy {
            Some(p) => ForwardOpts::with_policy(p.clone()),
            None => self.base_opts.clone(),
        };
        opts.cv_proxy = Some(self.telemetry.cv_sampler());
        opts
    }

    /// Power model for a captured generation, memoized per worker: epochs
    /// change at governor-dwell cadence (hundreds of ms), so the shared
    /// `powers` lock is only touched when the epoch actually moved — the
    /// steady-state batch path never contends on it.
    fn resolve_power<'c>(
        &self,
        stamped: &StampedPolicy,
        cache: &'c mut (u64, PowerModel),
    ) -> &'c PowerModel {
        if cache.0 != stamped.epoch {
            let power = self
                .powers
                .lock()
                .unwrap()
                .get(&stamped.epoch)
                .cloned()
                .unwrap_or_else(|| self.base_power.clone());
            *cache = (stamped.epoch, power);
        }
        &cache.1
    }
}

/// Cloneable hot-swap handle into a running pool: validates, **warms** and
/// atomically installs per-layer policies without owning the service (what
/// the QoS governor holds). Warming happens before the swap — the new
/// generation's `LayerPlan`s are built into the shared cache while the pool
/// still serves the old one, so a swap never stalls a worker on a plan
/// build (steady-state swaps between previously seen rungs are pure cache
/// hits).
#[derive(Clone)]
pub struct PolicyInstaller {
    engine: Arc<Engine>,
    switch: Arc<PolicySwitch>,
    powers: Arc<Mutex<HashMap<u64, PowerModel>>>,
    n_array: u32,
}

/// Epochs of power-model history kept for in-flight batches; a governed
/// service installs a new generation per dwell, so without a cap the map
/// would grow without bound. A batch only ever looks up the epoch it
/// captured at pop time, which is always among the most recent handful
/// (evicted epochs fall back to the start generation's power model).
const POWER_EPOCHS_KEPT: usize = 64;

impl PolicyInstaller {
    /// Install `policy` as the next generation; returns its epoch. Errors
    /// (layer-count mismatch) leave the current generation serving.
    pub fn install(&self, policy: SharedPolicy) -> Result<u64> {
        policy.validate_for(&self.engine.model).context("install policy")?;
        self.engine.prepare_plans_policy(&policy).context("install policy")?;
        let power = PowerModel::for_policy(&policy, &self.engine.model, self.n_array);
        // Publish under the powers lock so a worker that loads the fresh
        // epoch and immediately looks up its power blocks on this lock
        // instead of falling back to the base model.
        let mut powers = self.powers.lock().unwrap();
        let epoch = self.switch.install(Some(policy));
        powers.insert(epoch, power);
        while powers.len() > POWER_EPOCHS_KEPT {
            let oldest = *powers.keys().min().expect("nonempty map");
            powers.remove(&oldest);
        }
        Ok(epoch)
    }

    /// Epoch of the currently serving generation.
    pub fn epoch(&self) -> u64 {
        self.switch.epoch()
    }

    /// The served model (ladder validation).
    pub fn model(&self) -> &Model {
        &self.engine.model
    }
}

/// A running inference service: a worker pool over one shared engine.
pub struct InferenceService {
    queue: Arc<SharedQueue>,
    workers: Vec<JoinHandle<()>>,
    alive: Arc<AtomicUsize>,
    engine: Arc<Engine>,
    switch: Arc<PolicySwitch>,
    powers: Arc<Mutex<HashMap<u64, PowerModel>>>,
    n_array: u32,
    pub metrics: Arc<Metrics>,
    /// Power model of the generation the service STARTED with (epoch 0);
    /// per-request energy accounting follows the serving epoch.
    pub power: PowerModel,
    /// Live serving telemetry (latency ring, queue depth, batch occupancy,
    /// CV error proxy) — what the QoS governor polls.
    pub telemetry: Arc<Telemetry>,
}

impl InferenceService {
    /// Start the service over a prepared engine.
    ///
    /// Fails — before any worker thread spawns, so there is no pool to
    /// poison — when the effective per-layer policy (from
    /// `ServiceConfig::policy` or the `CVAPPROX_SERVICE_POLICY` file) does
    /// not parse or does not match the model's MAC layer count.
    pub fn start(engine: Engine, cfg: ServiceConfig) -> Result<InferenceService> {
        let policy = resolve_policy(
            cfg.policy.as_ref(),
            std::env::var("CVAPPROX_SERVICE_POLICY").ok().as_deref(),
        )?;
        let metrics = Arc::new(Metrics::new());
        let queue = Arc::new(SharedQueue::new());
        let telemetry = Arc::new(Telemetry::new(engine.model.mac_layers()));
        // Warm the weight-side plans once, before any worker spawns: the
        // pool shares one PlanCache through the Arc'd engine, so no request
        // on any worker pays the one-time build. With a policy, each layer
        // is warmed at its own point — and the layer-count validation
        // happens here, turning a bad policy into a start-time `Err`.
        let (power, base_opts) = match &policy {
            Some(p) => {
                p.validate_for(&engine.model).context("service policy")?;
                engine.prepare_plans_policy(p).context("service policy")?;
                (
                    PowerModel::for_policy(p, &engine.model, cfg.n_array),
                    ForwardOpts::with_policy(p.clone()),
                )
            }
            None => {
                engine.prepare_plans(cfg.family, cfg.m);
                (
                    PowerModel::new(cfg.family, cfg.m, cfg.n_array),
                    ForwardOpts::approx(cfg.family, cfg.m, cfg.use_cv),
                )
            }
        };
        // Generation 0 is the start configuration; its power model seeds
        // the epoch → power map the workers consult per batch.
        let switch = Arc::new(PolicySwitch::new(policy));
        let powers = Arc::new(Mutex::new(HashMap::from([(0u64, power.clone())])));
        // Anchor the throughput clock at "service ready" — after the plan
        // warm-up, so the one-time build does not deflate throughput /
        // occupancy, but before any request can complete, so even a
        // one-request session reports a rate. Also size the per-worker
        // counters for the whole pool so idle workers show up as zeros.
        metrics.mark_started();
        metrics.init_workers(cfg.workers.max(1));
        let engine = Arc::new(engine);
        let n_workers = cfg.workers.max(1);
        let alive = Arc::new(AtomicUsize::new(n_workers));
        let shared = WorkerShared {
            engine: engine.clone(),
            queue: queue.clone(),
            metrics: metrics.clone(),
            telemetry: telemetry.clone(),
            switch: switch.clone(),
            powers: powers.clone(),
            base_opts,
            base_power: power.clone(),
            alive: alive.clone(),
        };
        let workers = (0..n_workers)
            .map(|id| {
                let shared = shared.clone();
                let cfg = cfg.clone();
                std::thread::Builder::new()
                    .name(format!("cvapprox-worker-{id}"))
                    .spawn(move || worker_loop(id, shared, cfg))
                    .expect("spawn service worker")
            })
            .collect();
        Ok(InferenceService {
            queue,
            workers,
            alive,
            engine,
            switch,
            powers,
            n_array: cfg.n_array,
            metrics,
            power,
            telemetry,
        })
    }

    /// Hot-swap handle for governors/tests (see [`PolicyInstaller`]).
    pub fn installer(&self) -> PolicyInstaller {
        PolicyInstaller {
            engine: self.engine.clone(),
            switch: self.switch.clone(),
            powers: self.powers.clone(),
            n_array: self.n_array,
        }
    }

    /// Validate, warm and atomically install a new per-layer policy; new
    /// batches serve it immediately, in-flight batches complete on their
    /// captured generation. Returns the new epoch.
    pub fn install_policy(&self, policy: SharedPolicy) -> Result<u64> {
        self.installer().install(policy)
    }

    /// Epoch of the currently serving policy generation.
    pub fn current_epoch(&self) -> u64 {
        self.switch.epoch()
    }

    /// Live queue-depth probe the QoS governor polls at decision time: a
    /// saturated pool whose in-flight batches outlast a whole decision
    /// window completes nothing — indistinguishable from idle on the
    /// drained telemetry alone — but its backlog is visible here (queued
    /// work) and in `Telemetry::in_flight` (popped work), and together
    /// they keep the governor from "recovering" toward exact in the middle
    /// of that overload. One cheap lock per decision, not per batch.
    pub fn depth_probe(&self) -> Arc<dyn Fn() -> usize + Send + Sync> {
        let queue = self.queue.clone();
        Arc::new(move || queue.len())
    }

    /// Submit an image; returns a handle to wait on, or `Err` when the
    /// service is shut down / has no live workers (never panics).
    pub fn submit(&self, image: Tensor) -> Result<Pending> {
        if self.alive.load(Ordering::SeqCst) == 0 {
            bail!("inference service has no live workers");
        }
        let (rtx, rrx) = mpsc::sync_channel(1);
        let req = Request { image, enqueued: Instant::now(), respond: rtx };
        if self.queue.push(req).is_err() {
            bail!("inference service is shut down");
        }
        Ok(Pending { rx: rrx })
    }

    /// Submit and wait (convenience).
    pub fn infer(&self, image: Tensor) -> Result<Reply> {
        self.submit(image)?.wait()
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Stop accepting new requests; already-queued work still drains.
    /// Subsequent `submit`/`infer` calls return `Err`.
    pub fn close(&self) {
        self.queue.close();
    }

    /// Drain queued work, stop the pool, and return the final metrics.
    pub fn shutdown(mut self) -> MetricsSnapshot {
        self.stop_and_join();
        self.metrics.snapshot()
    }

    fn stop_and_join(&mut self) {
        self.queue.close();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for InferenceService {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

fn worker_loop(worker_id: usize, shared: WorkerShared, cfg: ServiceConfig) {
    let _guard = AliveGuard { alive: shared.alive.clone(), queue: shared.queue.clone() };
    let macs = shared.engine.model.macs();
    let input_shape = shared.engine.model.input_shape();
    // One scratch arena per worker, pre-grown to the model's worst-case
    // GEMM footprint at this batch size, so steady-state batches allocate
    // nothing on the GEMM path.
    let batch_cap = cfg.batch_size.max(1);
    let mut scratch = Scratch::new();
    let (panel, acc) = shared.engine.model.max_gemm_footprint();
    scratch.reserve(panel * batch_cap, acc * batch_cap);
    // Per-worker (epoch → power) memo: epoch 0 is the start generation.
    let mut power_cache: (u64, PowerModel) = (0, shared.base_power.clone());
    while let Some((batch, depth)) = shared.queue.pop_batch(batch_cap, cfg.batch_timeout) {
        if batch.is_empty() {
            continue;
        }
        // Split malformed images out before fusing, so one bad request
        // cannot poison the whole batched forward.
        let mut good: Vec<Request> = Vec::with_capacity(batch.len());
        for req in batch {
            let t = &req.image;
            if (t.h, t.w, t.c) == input_shape {
                good.push(req);
            } else {
                let _ = req.respond.send(Err(format!(
                    "input shape mismatch: got {}x{}x{}, model expects {}x{}x{}",
                    t.h, t.w, t.c, input_shape.0, input_shape.1, input_shape.2
                )));
            }
        }
        if good.is_empty() {
            continue;
        }
        // Capture the policy generation ONCE per batch: the whole batch
        // runs under this epoch's policy (a concurrent install affects only
        // later batches), which is exactly the hot-swap consistency
        // invariant the property tests pin.
        let stamped = shared.switch.load();
        let opts = shared.resolve_opts(&stamped);
        let power = shared.resolve_power(&stamped, &mut power_cache).clone();
        // Raise the in-flight gauge before the forward: requests inside an
        // executing batch are visible to neither the queue depth nor the
        // completion count, and the governor must not mistake a pool
        // saturated by long batches for an idle one.
        shared.telemetry.batch_started(good.len());
        let t0 = Instant::now();
        let imgs: Vec<&Tensor> = good.iter().map(|r| &r.image).collect();
        let result = shared.engine.forward_batch_with_scratch(&imgs, &opts, &mut scratch);
        drop(imgs);
        shared.metrics.record_batch(worker_id, good.len(), t0.elapsed());
        shared.telemetry.record_batch(good.len(), batch_cap, depth);
        match result {
            Ok(all_logits) => {
                for (req, logits) in good.into_iter().zip(all_logits) {
                    let queue_wait = t0.saturating_duration_since(req.enqueued);
                    let latency = req.enqueued.elapsed();
                    shared.metrics.record(latency, queue_wait, macs, &power);
                    shared.telemetry.record_latency(latency);
                    let reply = if !logits.is_empty()
                        && logits.iter().all(|v| v.is_nan())
                    {
                        Err("all logits are NaN (non-finite model output)".to_string())
                    } else {
                        Ok(Reply {
                            top1: argmax(&logits),
                            logits,
                            latency,
                            epoch: stamped.epoch,
                        })
                    };
                    let _ = req.respond.send(reply);
                }
            }
            Err(e) => {
                let msg = format!("batched forward failed: {e:#}");
                for req in good {
                    let queue_wait = t0.saturating_duration_since(req.enqueued);
                    let latency = req.enqueued.elapsed();
                    shared.metrics.record(latency, queue_wait, macs, &power);
                    shared.telemetry.record_latency(latency);
                    let _ = req.respond.send(Err(msg.clone()));
                }
            }
        }
    }
}

/// Index of the largest logit. NaN-safe: a NaN never wins (it ranks below
/// every real value — the `>=` against a NEG_INFINITY start admits every
/// non-NaN, including -∞ itself), ties keep the previous
/// `Iterator::max_by` semantics (last maximal index), and all-NaN or empty
/// input returns 0 — the old implementation's `partial_cmp().unwrap()`
/// panicked the worker thread on the first NaN instead.
pub fn argmax(xs: &[f64]) -> usize {
    let mut best = 0usize;
    let mut best_v = f64::NEG_INFINITY;
    for (i, &v) in xs.iter().enumerate() {
        if v >= best_v {
            best = i;
            best_v = v;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artifacts_dir;
    use crate::nn::loader;
    use crate::nn::testutil;

    fn artifact_engine() -> Option<Engine> {
        let path = artifacts_dir().join("models/mininet_synth10.cvm");
        if !path.exists() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        Some(Engine::new(loader::load_model(&path).unwrap()))
    }

    #[test]
    fn serves_requests_and_counts_metrics() {
        let Some(engine) = artifact_engine() else { return };
        let ds = crate::datasets::Dataset::load(
            &artifacts_dir().join("data/synth10_test.cvd"),
        )
        .unwrap();
        let cfg = ServiceConfig {
            family: Family::Perforated,
            m: 2,
            use_cv: true,
            batch_size: 4,
            ..Default::default()
        };
        let svc = InferenceService::start(engine, cfg).unwrap();
        let pendings: Vec<Pending> =
            (0..8).map(|i| svc.submit(ds.image(i)).unwrap()).collect();
        let mut correct = 0;
        for (i, p) in pendings.into_iter().enumerate() {
            let reply = p.wait().unwrap();
            assert_eq!(reply.logits.len(), 10);
            if reply.top1 == ds.label(i) {
                correct += 1;
            }
        }
        let snap = svc.shutdown();
        assert_eq!(snap.completed, 8);
        assert!(snap.batches >= 1 && snap.batches <= 8);
        assert!(snap.total_macs > 0);
        assert!(snap.energy_vs_exact < 1.0); // approximate design saves power
        assert!(correct >= 4, "sanity: {correct}/8 correct");
    }

    #[test]
    fn shutdown_is_clean_with_no_requests() {
        let svc = InferenceService::start(
            Engine::new(testutil::tiny_model()),
            ServiceConfig::default(),
        ).unwrap();
        let snap = svc.shutdown();
        assert_eq!(snap.completed, 0);
    }

    #[test]
    fn worker_pool_serves_concurrent_clients_bit_identically() {
        // N client threads hammer the pool; every reply must be bit-equal
        // to a single-threaded per-image forward on an identical engine,
        // and the batch/request counters must add up across workers.
        let model = testutil::tiny_model();
        let reference = Engine::new(model.clone());
        let cfg = ServiceConfig {
            family: Family::Truncated,
            m: 6,
            use_cv: true,
            workers: 4,
            batch_size: 4,
            ..Default::default()
        };
        let svc = InferenceService::start(Engine::new(model), cfg).unwrap();
        let opts = ForwardOpts::approx(Family::Truncated, 6, true);
        let clients = 6usize;
        let per_client = 8usize;
        std::thread::scope(|s| {
            for t in 0..clients {
                let svc = &svc;
                let reference = &reference;
                let opts = &opts;
                s.spawn(move || {
                    for i in 0..per_client {
                        let img = testutil::tiny_image((t * 100 + i) as u64);
                        let reply = svc.infer(img.clone()).unwrap();
                        let want = reference.forward(&img, opts).unwrap();
                        assert_eq!(reply.logits, want, "client {t} img {i}");
                        assert_eq!(reply.top1, argmax(&want));
                    }
                });
            }
        });
        let snap = svc.shutdown();
        assert_eq!(snap.completed, (clients * per_client) as u64);
        assert!(snap.batches >= 1);
        assert_eq!(snap.worker_batches.iter().sum::<u64>(), snap.batches);
        assert_eq!(snap.worker_requests.iter().sum::<u64>(), snap.completed);
        assert!(snap.mean_batch_size >= 1.0);
        assert!(snap.throughput_rps > 0.0);
    }

    #[test]
    fn burst_is_batched_and_bit_identical() {
        // A burst submitted up front exercises true batch fusion. Pool size
        // comes from the env-driven default so the CI sweep
        // (CVAPPROX_SERVICE_WORKERS=1 / 4 in scripts/verify.sh) runs this
        // at both sizes. The generous batch_timeout makes fusion
        // deterministic: the whole burst is enqueued within the first
        // batch's fill window, so 24 requests cannot come out as 24
        // singleton batches unless the batcher is broken.
        let model = testutil::tiny_model();
        let reference = Engine::new(model.clone());
        let cfg = ServiceConfig {
            family: Family::Perforated,
            m: 2,
            use_cv: true,
            // env-driven (the CI sweep pins 1 and 4) but capped well below
            // the 24-request burst: with ~one worker per request, each
            // push can legally wake a fresh worker into its own singleton
            // batch and the fusion assertion below would be meaningless.
            workers: default_service_workers().min(4),
            batch_size: 8,
            batch_timeout: Duration::from_millis(50),
            ..Default::default()
        };
        let svc = InferenceService::start(Engine::new(model), cfg).unwrap();
        let opts = ForwardOpts::approx(Family::Perforated, 2, true);
        let imgs: Vec<Tensor> =
            (0..24).map(|i| testutil::tiny_image(i as u64)).collect();
        let pendings: Vec<Pending> =
            imgs.iter().map(|im| svc.submit(im.clone()).unwrap()).collect();
        for (img, p) in imgs.iter().zip(pendings) {
            let reply = p.wait().unwrap();
            assert_eq!(reply.logits, reference.forward(img, &opts).unwrap());
        }
        let snap = svc.shutdown();
        assert_eq!(snap.completed, 24);
        assert!(
            snap.batches < snap.completed && snap.mean_batch_size > 1.0,
            "burst must fuse into multi-request batches: {} batches, mean {}",
            snap.batches,
            snap.mean_batch_size
        );
    }

    #[test]
    fn nan_logits_are_errors_not_panics() {
        // A model whose logits dequantize to NaN must not kill any worker:
        // requests answer with Err, the pool keeps serving, shutdown is
        // clean. (The seed's argmax panicked the worker on the first NaN
        // and the next submit panicked the caller.)
        let cfg = ServiceConfig {
            family: Family::Perforated,
            m: 2,
            use_cv: true,
            // env-driven default: the CI sweep runs this at 1 and 4 workers
            workers: default_service_workers(),
            batch_size: 4,
            ..Default::default()
        };
        let svc =
            InferenceService::start(Engine::new(testutil::nan_logit_model()), cfg).unwrap();
        for _ in 0..2 {
            let pend: Vec<Pending> = (0..4)
                .map(|i| svc.submit(testutil::tiny_image(i)).unwrap())
                .collect();
            for p in pend {
                let err = p.wait().unwrap_err();
                assert!(format!("{err:#}").contains("NaN"), "{err:#}");
            }
        }
        // still alive and accepting after 8 NaN results
        assert!(svc.submit(testutil::tiny_image(99)).is_ok());
        let snap = svc.shutdown();
        assert!(snap.completed >= 8);
    }

    #[test]
    fn submit_after_close_errors_instead_of_panicking() {
        let svc = InferenceService::start(
            Engine::new(testutil::tiny_model()),
            ServiceConfig { workers: 1, ..Default::default() },
        ).unwrap();
        let p = svc.submit(testutil::tiny_image(1)).unwrap();
        assert!(p.wait().is_ok());
        svc.close();
        assert!(svc.submit(testutil::tiny_image(2)).is_err());
        assert!(svc.infer(testutil::tiny_image(3)).is_err());
        let snap = svc.shutdown();
        assert_eq!(snap.completed, 1);
    }

    #[test]
    fn wrong_shape_request_fails_alone() {
        let model = testutil::tiny_model();
        let reference = Engine::new(model.clone());
        let svc = InferenceService::start(
            Engine::new(model),
            ServiceConfig { workers: 1, batch_size: 4, ..Default::default() },
        ).unwrap();
        let good = testutil::tiny_image(7);
        let bad = Tensor::new(2, 2, 1);
        let p_good = svc.submit(good.clone()).unwrap();
        let p_bad = svc.submit(bad).unwrap();
        let want = reference.forward(&good, &ForwardOpts::exact()).unwrap();
        assert_eq!(p_good.wait().unwrap().logits, want);
        let err = p_bad.wait().unwrap_err();
        assert!(format!("{err:#}").contains("shape"), "{err:#}");
        svc.shutdown();
    }

    #[test]
    fn single_request_session_reports_throughput() {
        let svc = InferenceService::start(
            Engine::new(testutil::tiny_model()),
            ServiceConfig { workers: 2, ..Default::default() },
        ).unwrap();
        svc.infer(testutil::tiny_image(0)).unwrap();
        let snap = svc.shutdown();
        assert_eq!(snap.completed, 1);
        assert!(
            snap.throughput_rps > 0.0,
            "one-request session must report a rate (was the start anchor lost?)"
        );
    }

    #[test]
    fn policy_service_serves_mixed_batches_bit_identically() {
        // The tentpole acceptance path: a mixed per-layer policy flows
        // through the worker pool (batched forwards, shared plan cache) and
        // every reply is bit-equal to the per-image policy forward.
        let model = testutil::tiny_model(); // 2 MAC layers
        let reference = Engine::new(model.clone());
        let policy = std::sync::Arc::new(
            LayerPolicy::from_ms(Family::Perforated, &[2, 0], true).unwrap(),
        );
        let cfg = ServiceConfig {
            policy: Some(policy.clone()),
            workers: 2,
            batch_size: 4,
            batch_timeout: Duration::from_millis(20),
            ..Default::default()
        };
        let svc = InferenceService::start(Engine::new(model), cfg).unwrap();
        let opts = ForwardOpts::with_policy(policy);
        let imgs: Vec<Tensor> =
            (0..16).map(|i| testutil::tiny_image(1000 + i)).collect();
        let pendings: Vec<Pending> =
            imgs.iter().map(|im| svc.submit(im.clone()).unwrap()).collect();
        for (img, p) in imgs.iter().zip(pendings) {
            let reply = p.wait().unwrap();
            assert_eq!(reply.logits, reference.forward(img, &opts).unwrap());
        }
        // Wrong-shape requests still fail alone under a policy config.
        let err = svc.infer(Tensor::new(2, 2, 1)).unwrap_err();
        assert!(format!("{err:#}").contains("shape"), "{err:#}");
        let snap = svc.shutdown();
        assert_eq!(snap.completed, 16);
        // Mixed power estimate: strictly between the aggressive uniform
        // point and exact.
        let uniform = PowerModel::new(Family::Perforated, 2, 64).power_norm;
        assert!(snap.energy_vs_exact > uniform && snap.energy_vs_exact < 1.0);
    }

    #[test]
    fn paired_policy_service_serves_bit_identically() {
        // A positive/negative paired policy flows through the worker pool
        // (batched forwards, shared paired-plan cache) and every reply is
        // bit-equal to the per-image paired forward; the estimated power of
        // a mirrored pairing equals the uniform point's.
        let model = testutil::tiny_model(); // 2 MAC layers
        let reference = Engine::new(model.clone());
        let policy = std::sync::Arc::new(
            LayerPolicy::paired_uniform(Family::Perforated, 2, true, 2).unwrap(),
        );
        let cfg = ServiceConfig {
            policy: Some(policy.clone()),
            workers: 2,
            batch_size: 4,
            batch_timeout: Duration::from_millis(20),
            ..Default::default()
        };
        let svc = InferenceService::start(Engine::new(model), cfg).unwrap();
        let opts = ForwardOpts::with_policy(policy);
        let imgs: Vec<Tensor> =
            (0..12).map(|i| testutil::tiny_image(2000 + i)).collect();
        let pendings: Vec<Pending> =
            imgs.iter().map(|im| svc.submit(im.clone()).unwrap()).collect();
        for (img, p) in imgs.iter().zip(pendings) {
            let reply = p.wait().unwrap();
            assert_eq!(reply.logits, reference.forward(img, &opts).unwrap());
        }
        let snap = svc.shutdown();
        assert_eq!(snap.completed, 12);
        let uniform = PowerModel::new(Family::Perforated, 2, 64).power_norm;
        assert!(
            (snap.energy_vs_exact - uniform).abs() < 1e-9,
            "mirrored pairing is power-neutral vs the uniform point: {} vs {uniform}",
            snap.energy_vs_exact
        );
    }

    #[test]
    fn start_rejects_mismatched_policy_before_spawning() {
        // 3 policy layers vs tiny_model's 2 MAC layers: start must fail
        // (nothing spawns, nothing to poison) — and a subsequent valid
        // service on the same config shape works fine.
        let bad = std::sync::Arc::new(
            LayerPolicy::uniform(Family::Perforated, 2, true, 3).unwrap(),
        );
        let err = InferenceService::start(
            Engine::new(testutil::tiny_model()),
            ServiceConfig { policy: Some(bad), workers: 2, ..Default::default() },
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("MAC layers"), "{err:#}");
        let good = std::sync::Arc::new(
            LayerPolicy::uniform(Family::Perforated, 2, true, 2).unwrap(),
        );
        let svc = InferenceService::start(
            Engine::new(testutil::tiny_model()),
            ServiceConfig { policy: Some(good), workers: 2, ..Default::default() },
        )
        .unwrap();
        assert!(svc.infer(testutil::tiny_image(5)).is_ok());
        svc.shutdown();
    }

    #[test]
    fn resolve_policy_sources_and_errors() {
        let dir = std::env::temp_dir();
        let ok_path = dir.join(format!("cvapprox_policy_ok_{}.txt", std::process::id()));
        let bad_path = dir.join(format!("cvapprox_policy_bad_{}.txt", std::process::id()));
        std::fs::write(&ok_path, "perforated 2 cv\nexact\n").unwrap();
        std::fs::write(&bad_path, "bogusfamily 2 cv\n").unwrap();

        // No sources -> no policy.
        assert!(resolve_policy(None, None).unwrap().is_none());
        assert!(resolve_policy(None, Some("  ")).unwrap().is_none());
        // Env path loads the file.
        let loaded = resolve_policy(None, Some(ok_path.to_str().unwrap()))
            .unwrap()
            .unwrap();
        assert_eq!(loaded.len(), 2);
        assert_eq!(loaded.approx_layers(), 1);
        // Unknown family / missing file surface as Err, tagged with the knob.
        let err = resolve_policy(None, Some(bad_path.to_str().unwrap())).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("CVAPPROX_SERVICE_POLICY"), "{msg}");
        assert!(msg.contains("unknown family"), "{msg}");
        assert!(resolve_policy(None, Some("/nonexistent/policy.json")).is_err());
        // Explicit config policy wins over the env path.
        let explicit = std::sync::Arc::new(
            LayerPolicy::uniform(Family::Truncated, 6, true, 2).unwrap(),
        );
        let got = resolve_policy(
            Some(&explicit),
            Some(bad_path.to_str().unwrap()),
        )
        .unwrap()
        .unwrap();
        assert_eq!(
            got.as_uniform().unwrap(),
            crate::nn::LayerPoint::new(Family::Truncated, 6, true)
        );
        let _ = std::fs::remove_file(&ok_path);
        let _ = std::fs::remove_file(&bad_path);
    }

    #[test]
    fn hot_swap_replies_bit_identical_under_concurrent_random_swaps() {
        // The hot-swap consistency property: while a swapper thread installs
        // random ladder rungs into the live pool, every reply must be
        // bit-identical to a single-policy forward under the rung its epoch
        // names — i.e. no batch ever mixes two policies, and the epoch
        // stamp is never wrong.
        let model = testutil::tiny_model(); // 2 MAC layers
        let rungs: Vec<SharedPolicy> = vec![
            Arc::new(LayerPolicy::uniform(Family::Exact, 0, false, 2).unwrap()),
            Arc::new(LayerPolicy::from_ms(Family::Perforated, &[2, 0], true).unwrap()),
            Arc::new(LayerPolicy::paired_uniform(Family::Perforated, 2, true, 2).unwrap()),
            Arc::new(LayerPolicy::uniform(Family::Truncated, 6, true, 2).unwrap()),
        ];
        let svc = InferenceService::start(
            Engine::new(model.clone()),
            ServiceConfig {
                workers: 3,
                batch_size: 4,
                batch_timeout: Duration::from_micros(500),
                ..Default::default()
            },
        )
        .unwrap();
        // epoch -> rung index; epoch 0 is the start config (uniform exact),
        // which rungs[0] reproduces bit-for-bit.
        let epoch_map: Mutex<std::collections::HashMap<u64, usize>> =
            Mutex::new(std::collections::HashMap::from([(0u64, 0usize)]));
        let reference = Engine::new(model);
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let clients = 4usize;
        let per_client = 40usize;
        let mut seen_epochs = std::collections::HashSet::new();
        std::thread::scope(|s| {
            // Swapper: random-ish walk over the rungs, installing under the
            // epoch_map lock so clients can always resolve a reply's epoch.
            {
                let svc = &svc;
                let epoch_map = &epoch_map;
                let rungs = &rungs;
                let stop = stop.clone();
                s.spawn(move || {
                    let mut i = 1usize;
                    while !stop.load(Ordering::SeqCst) {
                        let r = (i * 7 + 3) % rungs.len();
                        let mut map = epoch_map.lock().unwrap();
                        let epoch = svc.install_policy(rungs[r].clone()).unwrap();
                        map.insert(epoch, r);
                        drop(map);
                        i += 1;
                        std::thread::sleep(Duration::from_micros(300));
                    }
                });
            }
            let mut handles = Vec::new();
            for t in 0..clients {
                let svc = &svc;
                let reference = &reference;
                let epoch_map = &epoch_map;
                let rungs = &rungs;
                handles.push(s.spawn(move || {
                    let mut epochs = Vec::new();
                    for i in 0..per_client {
                        let img = testutil::tiny_image((t * 1000 + i) as u64);
                        let reply = svc.infer(img.clone()).unwrap();
                        let rung = {
                            // The swapper publishes the mapping under the
                            // same lock it installs under, so the reply's
                            // epoch is always resolvable.
                            let map = epoch_map.lock().unwrap();
                            *map.get(&reply.epoch).unwrap_or_else(|| {
                                panic!("reply epoch {} not in map", reply.epoch)
                            })
                        };
                        let opts = ForwardOpts::with_policy(rungs[rung].clone());
                        let want = reference.forward(&img, &opts).unwrap();
                        assert_eq!(
                            reply.logits, want,
                            "client {t} img {i}: reply (epoch {}, rung {rung}) \
                             not bit-identical to its rung's static forward",
                            reply.epoch
                        );
                        epochs.push(reply.epoch);
                    }
                    epochs
                }));
            }
            let mut all = Vec::new();
            for h in handles {
                all.extend(h.join().unwrap());
            }
            stop.store(true, Ordering::SeqCst);
            seen_epochs.extend(all);
        });
        let snap = svc.shutdown();
        assert_eq!(snap.completed, (clients * per_client) as u64);
        assert!(
            seen_epochs.len() >= 2,
            "swaps never landed mid-traffic (epochs {seen_epochs:?})"
        );
    }

    #[test]
    fn shutdown_drains_queue_while_policies_step() {
        // Satellite: shutdown must drain every queued request to an Ok
        // reply even while a stepping thread keeps hot-swapping policies.
        let model = testutil::tiny_model();
        let rungs: Vec<SharedPolicy> = vec![
            Arc::new(LayerPolicy::uniform(Family::Exact, 0, false, 2).unwrap()),
            Arc::new(LayerPolicy::from_ms(Family::Perforated, &[2, 0], true).unwrap()),
            Arc::new(LayerPolicy::uniform(Family::Perforated, 3, true, 2).unwrap()),
        ];
        let svc = InferenceService::start(
            Engine::new(model),
            ServiceConfig {
                workers: 2,
                batch_size: 4,
                batch_timeout: Duration::from_millis(1),
                ..Default::default()
            },
        )
        .unwrap();
        let installer = svc.installer();
        let pendings: Vec<Pending> = (0..64)
            .map(|i| svc.submit(testutil::tiny_image(i)).unwrap())
            .collect();
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let stepper = {
            let stop = stop.clone();
            let rungs = rungs.clone();
            std::thread::spawn(move || {
                let mut i = 0usize;
                while !stop.load(Ordering::SeqCst) {
                    installer.install(rungs[i % rungs.len()].clone()).unwrap();
                    i += 1;
                    std::thread::sleep(Duration::from_micros(200));
                }
                i
            })
        };
        let snap = svc.shutdown();
        stop.store(true, Ordering::SeqCst);
        let steps = stepper.join().unwrap();
        assert_eq!(snap.completed, 64, "shutdown must drain the whole queue");
        for p in pendings {
            p.wait().unwrap();
        }
        assert!(steps >= 1, "the stepper never stepped");
    }

    #[test]
    fn install_policy_swaps_between_requests_and_rejects_bad_policies() {
        let model = testutil::tiny_model();
        let reference = Engine::new(model.clone());
        let svc = InferenceService::start(
            Engine::new(model),
            ServiceConfig { workers: 1, ..Default::default() },
        )
        .unwrap();
        assert_eq!(svc.current_epoch(), 0);
        let img = testutil::tiny_image(11);
        let r0 = svc.infer(img.clone()).unwrap();
        assert_eq!(r0.epoch, 0);
        assert_eq!(r0.logits, reference.forward(&img, &ForwardOpts::exact()).unwrap());
        // Install an approximate policy; subsequent replies serve it.
        let p = Arc::new(LayerPolicy::uniform(Family::Perforated, 2, true, 2).unwrap());
        let epoch = svc.install_policy(p.clone()).unwrap();
        assert_eq!(epoch, 1);
        assert_eq!(svc.current_epoch(), 1);
        let r1 = svc.infer(img.clone()).unwrap();
        assert_eq!(r1.epoch, 1);
        assert_eq!(r1.logits, reference.forward(&img, &ForwardOpts::with_policy(p)).unwrap());
        // A mismatched policy is rejected and leaves the pool serving.
        let bad = Arc::new(LayerPolicy::uniform(Family::Perforated, 2, true, 5).unwrap());
        let err = svc.install_policy(bad).unwrap_err();
        assert!(format!("{err:#}").contains("MAC layers"), "{err:#}");
        assert_eq!(svc.current_epoch(), 1, "failed install must not bump the epoch");
        assert!(svc.infer(testutil::tiny_image(12)).is_ok());
        // Energy accounting follows the serving rung: the approximate rung
        // must have pulled the blended energy below exact.
        let snap = svc.shutdown();
        assert!(snap.energy_vs_exact < 1.0, "{}", snap.energy_vs_exact);
    }

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[1.0, 5.0, 3.0]), 1);
        assert_eq!(argmax(&[]), 0);
    }

    #[test]
    fn argmax_is_nan_safe() {
        assert_eq!(argmax(&[f64::NAN, 1.0, f64::NAN, 0.5]), 1);
        assert_eq!(argmax(&[f64::NAN, f64::NAN]), 0);
        assert_eq!(argmax(&[-1.0, f64::NAN]), 0);
        // ties keep last-max semantics, matching the old Iterator::max_by
        assert_eq!(argmax(&[2.0, 2.0, 1.0]), 1);
        assert_eq!(argmax(&[f64::NEG_INFINITY, f64::NEG_INFINITY]), 1);
    }
}
