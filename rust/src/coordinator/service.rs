//! The inference service: sharded work-stealing request queues → dynamic
//! batcher → supervised, self-healing worker pool, with multi-tenant QoS
//! classes.
//!
//! std-threads + Mutex/Condvar shards (no tokio in the offline vendor
//! set). Requests are submitted from any thread and land on a shard by
//! round-robin; each pool worker drains its **home shard** (worker id mod
//! shard count) into batches of up to `batch_size`, **steals** from
//! sibling shards when its own is empty, fuses the batch through
//! [`Engine::forward_batch_with_scratch`] — **one wide GEMM per layer**,
//! the weight-side plan amortized over every image — and answers each
//! request through its own oneshot channel. `CVAPPROX_SHARDS` (or
//! [`ServiceConfig::shards`]) sets the shard count; `auto`/unset means one
//! shard per worker, and `shards == 1` reproduces the single-queue
//! serving order exactly.
//!
//! **Multi-tenant classes:** every request carries a tenant/SLO class
//! ([`TenantClass`], class 0 = the default tenant). Each class gets its
//! own admission bound, its own default deadline, its own
//! [`PolicySwitch`] (so a per-class QoS governor can step one tenant down
//! its ladder without touching another's accuracy), and its own partition
//! of the shared [`Telemetry`] plane. Batches never mix classes — a batch
//! runs under exactly one class's policy generation, which is what keeps
//! the PR 5 hot-swap bit-identity invariant *per tenant*.
//!
//! **Deadline-aware batching (the PR 9 headline bugfix):** the dynamic
//! batcher's fill-wait used to run the full `batch_timeout` even when a
//! request already in the batch had a deadline due sooner — a lone
//! tight-deadline request was held past its budget and then rejected at
//! the dequeue screen. The fill-wait is now capped at the earliest
//! deadline in the batch (minus [`DEADLINE_FILL_MARGIN`] so the screen
//! and forward still fit), and skipped outright when nothing else is
//! queued and another worker sits idle (batching gains nothing when spare
//! capacity exists).
//!
//! Hardening invariants (tested below):
//! * Every accepted request gets **exactly one reply**: `Ok(Reply)` or a
//!   typed [`ReplyError`] — never a hang, never a panic at the caller.
//! * A crashed worker (injected or organic panic) answers its in-flight
//!   batch with [`ReplyError::WorkerCrashed`] and retires; the supervisor
//!   thread respawns a replacement (fresh scratch, exponential backoff),
//!   so the pool heals instead of shrinking to zero.
//! * Cache corruption (flipped LUT / plan-panel bits, injected via
//!   [`crate::fault::FaultPlan`] or real) is detected by checksums plus the
//!   CV-residual band monitor, healed in place
//!   ([`Engine::heal_integrity`]), and the affected batch is **replayed** —
//!   no silently-corrupted reply ever leaves the pool.
//! * Locks never cascade a crash: all queue/metrics state uses the
//!   poison-tolerant helpers in [`crate::util::sync`].
//! * NaN logits never panic a worker: [`argmax`] ranks NaN below every real
//!   value, and an all-NaN output answers the request with `Err` instead of
//!   a garbage class.
//! * A malformed (wrong-shape) image fails alone; it is split out before
//!   the batch is fused so neighbors still get answers.
//! * A bad per-layer policy (`ServiceConfig::policy` /
//!   `CVAPPROX_SERVICE_POLICY`) fails at `start` — before any worker
//!   spawns — so it can never poison a live pool.
//! * Admission control: an optional bounded queue rejects with
//!   [`ReplyError::Overloaded`] instead of buffering without bound, and
//!   per-request deadlines are enforced at dequeue
//!   ([`ReplyError::Deadline`]).

use std::collections::{HashMap, VecDeque};
use std::panic::{AssertUnwindSafe, catch_unwind};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, SyncSender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::metrics::{Metrics, MetricsSnapshot, PowerModel};
use crate::approx::Family;
use crate::fault::{Backoff, BatchFaults, FaultConfig, FaultPlan, IntegrityMonitor, retry};
use crate::nn::{
    CvProxySampler, Engine, ForwardOpts, LayerPolicy, Model, PolicySwitch, Scratch,
    SharedPolicy, StampedPolicy, Tensor,
};
use crate::qos::Telemetry;
use crate::util::sync::{lock_clean, wait_clean, wait_timeout_clean};
use crate::util::threadpool::default_workers;

/// Worker-pool size: `CVAPPROX_SERVICE_WORKERS` when set to a positive
/// integer (the CI serving smoke pins 1 and 4), else
/// `available_parallelism / CVAPPROX_THREADS` — pool workers and intra-GEMM
/// threads multiply, so the default divides the cores between the two
/// levels instead of oversubscribing quadratically (16 cores with the
/// default GEMM threading would otherwise run up to 256 runnable threads).
/// Read per service start (not cached) so tests and harnesses can vary it.
pub fn default_service_workers() -> usize {
    std::env::var("CVAPPROX_SERVICE_WORKERS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&v| v >= 1)
        .unwrap_or_else(|| {
            (default_workers() / crate::util::threadpool::configured_workers()).max(1)
        })
        .clamp(1, 256)
}

/// One tenant/SLO class served by the pool. Class index 0 is the default
/// tenant every plain `submit` lands on; additional classes get their own
/// admission bound, default deadline, policy switch (ladder rung) and
/// telemetry window, so one tenant degrading under load never moves
/// another tenant's accuracy.
#[derive(Clone, Debug)]
pub struct TenantClass {
    /// Human-readable name (surfaces in metrics snapshots and bench rows).
    pub name: String,
    /// Per-class admission bound across all shards; `0` = unbounded.
    pub queue_cap: usize,
    /// Latency budget applied when a submit for this class carries no
    /// explicit deadline; `None` = no implicit deadline.
    pub default_deadline: Option<Duration>,
}

impl TenantClass {
    pub fn new(name: &str) -> TenantClass {
        TenantClass { name: name.to_string(), queue_cap: 0, default_deadline: None }
    }
}

/// Parse a `CVAPPROX_TENANT_CLASSES` spec: comma-separated
/// `name[:cap=N][:deadline_ms=N]` entries, e.g.
/// `interactive:cap=64:deadline_ms=20,batchy:cap=256`. Invalid entries are
/// rejected (the service refuses to start on a malformed spec rather than
/// silently serving the wrong QoS contract).
fn parse_tenant_spec(spec: &str) -> Result<Vec<TenantClass>> {
    let mut classes = Vec::new();
    for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
        let mut parts = entry.split(':');
        let name = parts.next().unwrap_or_default().trim();
        if name.is_empty() {
            anyhow::bail!("CVAPPROX_TENANT_CLASSES: empty class name in {entry:?}");
        }
        let mut class = TenantClass::new(name);
        for opt in parts {
            match opt.split_once('=') {
                Some(("cap", v)) => {
                    class.queue_cap = v
                        .trim()
                        .parse()
                        .with_context(|| format!("CVAPPROX_TENANT_CLASSES: bad cap in {entry:?}"))?;
                }
                Some(("deadline_ms", v)) => {
                    let ms: u64 = v.trim().parse().with_context(|| {
                        format!("CVAPPROX_TENANT_CLASSES: bad deadline_ms in {entry:?}")
                    })?;
                    class.default_deadline = Some(Duration::from_millis(ms));
                }
                _ => anyhow::bail!("CVAPPROX_TENANT_CLASSES: unknown option in {entry:?}"),
            }
        }
        classes.push(class);
    }
    if classes.is_empty() {
        anyhow::bail!("CVAPPROX_TENANT_CLASSES: no classes in {spec:?}");
    }
    Ok(classes)
}

/// Resolve the shard count: an explicit positive `ServiceConfig::shards`
/// wins, else `CVAPPROX_SHARDS` (a positive integer, or `auto`), else one
/// shard per worker. Clamped to the worker count — a shard with no home
/// worker would only ever drain through steals.
fn resolve_shards(configured: usize, workers: usize) -> usize {
    let v = if configured > 0 {
        configured
    } else {
        std::env::var("CVAPPROX_SHARDS")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .filter(|&v| v >= 1)
            .unwrap_or(workers)
    };
    v.clamp(1, workers.max(1))
}

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub family: Family,
    pub m: u32,
    pub use_cv: bool,
    /// Per-layer heterogeneous policy. When set it supersedes the uniform
    /// `family`/`m`/`use_cv` triple: every worker serves mixed-m batches,
    /// each layer at its policy point, sharing one plan cache. When unset,
    /// `InferenceService::start` also consults `CVAPPROX_SERVICE_POLICY`
    /// (path to a JSON/text policy file — see `nn::policy`).
    pub policy: Option<SharedPolicy>,
    /// Simulated MAC array dimension (for the power model).
    pub n_array: u32,
    /// Pool workers sharing one engine (plans/LUT) with one scratch each.
    pub workers: usize,
    /// Max requests fused into one worker batch (one wide GEMM per layer).
    pub batch_size: usize,
    /// How long the batcher waits to fill a batch before running a partial
    /// one.
    pub batch_timeout: Duration,
    /// Admission-queue bound: `0` (default) keeps the historic unbounded
    /// queue; a positive cap rejects excess submits with
    /// [`ReplyError::Overloaded`] instead of buffering without bound.
    /// Applies to the default tenant class when `tenants` is empty.
    pub queue_cap: usize,
    /// Work-stealing shard count: `0` (default) consults `CVAPPROX_SHARDS`
    /// (`auto`/unset = one shard per worker); `1` reproduces the legacy
    /// single-queue serving order exactly. Always clamped to `workers`.
    pub shards: usize,
    /// Tenant/SLO classes. Empty (default) means one class named
    /// `default` whose admission bound is `queue_cap`; `start` also
    /// consults `CVAPPROX_TENANT_CLASSES` when empty (see
    /// [`TenantClass`]). Class 0 serves plain `submit` calls.
    pub tenants: Vec<TenantClass>,
    /// Deterministic fault injection (chaos testing). `None` — the default
    /// unless `CVAPPROX_FAULT_SEED` is set — costs nothing on the batch
    /// path. `Some` attaches a seeded [`FaultPlan`] and switches the pool
    /// into chaos mode: per-batch integrity verification instead of the
    /// periodic sweep.
    pub faults: Option<FaultConfig>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            family: Family::Exact,
            m: 0,
            use_cv: false,
            policy: None,
            n_array: 64,
            workers: default_service_workers(),
            batch_size: 8,
            batch_timeout: Duration::from_millis(2),
            queue_cap: 0,
            shards: 0,
            tenants: Vec::new(),
            faults: FaultConfig::from_env(),
        }
    }
}

/// Resolve the effective policy for a service: an explicit
/// `ServiceConfig::policy` wins; otherwise `env_path` (the value of
/// `CVAPPROX_SERVICE_POLICY`) names a policy file to load. Factored out of
/// `start` so the file/parse error paths are unit-testable without touching
/// process-global env state.
fn resolve_policy(
    explicit: Option<&SharedPolicy>,
    env_path: Option<&str>,
) -> Result<Option<SharedPolicy>> {
    if let Some(p) = explicit {
        return Ok(Some(p.clone()));
    }
    match env_path.map(str::trim) {
        Some(path) if !path.is_empty() => {
            let policy = LayerPolicy::load(std::path::Path::new(path))
                .context("CVAPPROX_SERVICE_POLICY")?;
            Ok(Some(std::sync::Arc::new(policy)))
        }
        _ => Ok(None),
    }
}

/// Typed terminal outcome of a request that could not be served. Every
/// accepted request resolves to `Ok(Reply)` or exactly one of these — the
/// serving plane never panics a caller and never leaves a `Pending`
/// hanging.
#[derive(Clone, Debug, Eq, PartialEq)]
pub enum ReplyError {
    /// The service is shut down (or was closed before the submit).
    Closed,
    /// The bounded admission queue was full (see `ServiceConfig::queue_cap`).
    Overloaded,
    /// The request's deadline expired before a worker dequeued it.
    Deadline,
    /// The serving worker crashed (or chaos dropped the reply) before the
    /// answer could be delivered; the batch was not silently corrupted —
    /// it simply never completed. Retryable.
    WorkerCrashed,
    /// The request itself is unserviceable: wrong input shape, or the model
    /// produced no finite logits for it.
    BadInput(String),
    /// Batch integrity could not be re-established within the replay
    /// budget (persistent corruption faster than healing).
    Integrity,
}

impl ReplyError {
    /// Whether a client-side retry can plausibly succeed: transient
    /// capacity/crash conditions are retryable, terminal states and
    /// per-request defects are not.
    pub fn retryable(&self) -> bool {
        matches!(self, ReplyError::Overloaded | ReplyError::WorkerCrashed)
    }
}

impl std::fmt::Display for ReplyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ReplyError::Closed => f.write_str("inference service is shut down"),
            ReplyError::Overloaded => {
                f.write_str("inference service overloaded: request rejected at admission")
            }
            ReplyError::Deadline => f.write_str("request deadline expired before execution"),
            ReplyError::WorkerCrashed => {
                f.write_str("worker crashed before the reply could be delivered")
            }
            ReplyError::BadInput(msg) => f.write_str(msg),
            ReplyError::Integrity => f.write_str(
                "batch integrity could not be re-established within the replay budget",
            ),
        }
    }
}

impl std::error::Error for ReplyError {}

/// One classification result.
#[derive(Clone, Debug)]
pub struct Reply {
    pub logits: Vec<f64>,
    pub top1: usize,
    pub latency: Duration,
    /// Policy generation that served this request (see
    /// [`crate::nn::PolicySwitch`]): the whole batch this request was fused
    /// into ran under exactly this epoch's policy, so the reply is
    /// bit-identical to a static forward under that generation — the
    /// hot-swap consistency anchor (property-tested below).
    pub epoch: u64,
    /// Tenant class that served this request (0 = default tenant). The
    /// fused batch ran under exactly this class's policy generation.
    pub tenant: usize,
}

struct Request {
    image: Tensor,
    enqueued: Instant,
    /// Absolute deadline; enforced at dequeue time (a worker never spends a
    /// batch slot on a request its client has already abandoned).
    deadline: Option<Instant>,
    /// Tenant class index (validated at submit; always < the class count).
    class: usize,
    respond: SyncSender<std::result::Result<Reply, ReplyError>>,
}

/// Handle for a submitted request.
pub struct Pending {
    rx: Receiver<std::result::Result<Reply, ReplyError>>,
}

impl Pending {
    /// Block until the reply arrives; typed errors. A dropped reply channel
    /// (worker died between dequeue and answer, or chaos dropped the batch)
    /// maps to [`ReplyError::WorkerCrashed`] — the caller always gets a
    /// terminal answer.
    pub fn wait_reply(self) -> std::result::Result<Reply, ReplyError> {
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => Err(ReplyError::WorkerCrashed),
        }
    }

    /// Block until the reply arrives (anyhow-flavored convenience).
    pub fn wait(self) -> Result<Reply> {
        self.wait_reply().map_err(anyhow::Error::from)
    }
}

/// How often a worker with an empty home shard re-polls its siblings for
/// stealable work while parked (multi-shard pools use a timed wait so a
/// push to a foreign shard is never missed; `shards == 1` with a single
/// tenant class keeps the legacy untimed wait).
const STEAL_POLL: Duration = Duration::from_micros(200);

/// Safety margin subtracted from the earliest in-batch deadline when
/// capping the fill-wait: the batch must leave the wait early enough to
/// pass the dequeue-time deadline screen and still execute.
const DEADLINE_FILL_MARGIN: Duration = Duration::from_millis(1);

/// One work-stealing shard: a Mutex'd set of per-class FIFOs plus a
/// Condvar for the workers homed on it. All lock operations are
/// poison-tolerant — a worker that panics while a sibling waits must not
/// wedge the queue.
struct Shard {
    inner: Mutex<ShardInner>,
    cv: Condvar,
}

struct ShardInner {
    /// One FIFO per tenant class. Batches never mix classes (each class
    /// runs its own policy generation), so the batcher drains exactly one
    /// of these per pop — the one whose head request is oldest.
    lanes: Vec<VecDeque<Request>>,
    closed: bool,
}

/// Sharded MPMC request queue feeding the worker pool. Submitters place
/// requests on shards round-robin; each worker drains its home shard
/// (worker id mod shard count) and steals from siblings when home is
/// empty, so a hot submitter never serializes the whole pool on one lock.
/// Admission bounds are per tenant class and global across shards
/// (enforced with an atomic ticket, so the cap is exact even under
/// concurrent multi-shard pushes). The dynamic-batching fill-wait is
/// deadline-aware — see [`ShardedQueue::pop_batch`].
struct ShardedQueue {
    shards: Vec<Shard>,
    /// Per-class admission bounds (`0` = unbounded), fixed at start.
    class_caps: Vec<usize>,
    /// Per-class queued counts across all shards: the admission ticket
    /// (incremented on push, decremented when a request leaves a lane) and
    /// the depth probes read by governors.
    class_queued: Vec<AtomicUsize>,
    /// Round-robin push cursor.
    rr: AtomicUsize,
    /// Workers currently parked waiting for work — the pool-idle signal
    /// that lets `pop_batch` skip a pointless fill-wait.
    idle_workers: AtomicUsize,
}

/// Index of the lane whose head request has waited longest (FIFO-fair
/// across tenant classes), or `None` when every lane is empty.
fn oldest_lane(lanes: &[VecDeque<Request>]) -> Option<usize> {
    lanes
        .iter()
        .enumerate()
        .filter_map(|(i, q)| q.front().map(|r| (r.enqueued, i)))
        .min_by_key(|&(t, _)| t)
        .map(|(_, i)| i)
}

impl ShardedQueue {
    fn new(shards: usize, class_caps: Vec<usize>) -> ShardedQueue {
        let n_classes = class_caps.len().max(1);
        let shard = || Shard {
            inner: Mutex::new(ShardInner {
                lanes: (0..n_classes).map(|_| VecDeque::new()).collect(),
                closed: false,
            }),
            cv: Condvar::new(),
        };
        ShardedQueue {
            shards: (0..shards.max(1)).map(|_| shard()).collect(),
            class_queued: (0..n_classes).map(|_| AtomicUsize::new(0)).collect(),
            class_caps,
            rr: AtomicUsize::new(0),
            idle_workers: AtomicUsize::new(0),
        }
    }

    fn n_shards(&self) -> usize {
        self.shards.len()
    }

    fn n_classes(&self) -> usize {
        self.class_caps.len()
    }

    /// Whether more than one tenant class or shard is live — the
    /// single-class single-shard case keeps the legacy untimed park (a
    /// fill-waiting sibling always consumes the wakeups it is handed);
    /// every other shape parks with a [`STEAL_POLL`] timeout so work on a
    /// foreign shard or lane is never stranded behind a consumed
    /// `notify_one` token.
    fn timed_park(&self) -> bool {
        self.shards.len() > 1 || self.class_caps.len() > 1
    }

    /// Enqueue unless closed or the class is at its admission bound; hands
    /// the request back with the rejection reason so the caller can answer
    /// it. Closed is checked under the target shard's lock (same lock as
    /// `close`, so no request can slip in after the drain decision); the
    /// cap is an atomic compare-and-swap ticket, exact across shards.
    fn push(&self, req: Request) -> std::result::Result<(), (Request, ReplyError)> {
        let class = req.class;
        let idx = self.rr.fetch_add(1, Ordering::Relaxed) % self.shards.len();
        let shard = &self.shards[idx];
        let mut g = lock_clean(&shard.inner);
        if g.closed {
            return Err((req, ReplyError::Closed));
        }
        let cap = self.class_caps[class];
        if cap > 0 {
            let admitted = self.class_queued[class]
                .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| (v < cap).then_some(v + 1));
            if admitted.is_err() {
                return Err((req, ReplyError::Overloaded));
            }
        } else {
            self.class_queued[class].fetch_add(1, Ordering::SeqCst);
        }
        g.lanes[class].push_back(req);
        drop(g);
        shard.cv.notify_one();
        Ok(())
    }

    /// Stop accepting; queued work still drains. Wakes every worker so
    /// idle ones can exit.
    fn close(&self) {
        for shard in &self.shards {
            lock_clean(&shard.inner).closed = true;
            shard.cv.notify_all();
        }
    }

    fn is_closed(&self) -> bool {
        lock_clean(&self.shards[0].inner).closed
    }

    /// Total queued depth across shards and classes (governor telemetry;
    /// racy by nature, no locks taken).
    fn len(&self) -> usize {
        (0..self.class_queued.len())
            .map(|i| self.class_queued[i].load(Ordering::SeqCst))
            .sum()
    }

    /// Queued depth of one tenant class across all shards.
    fn class_len(&self, class: usize) -> usize {
        if class < self.class_queued.len() {
            self.class_queued[class].load(Ordering::SeqCst)
        } else {
            0
        }
    }

    /// Answer every still-queued request with the given typed error — used
    /// when the pool drains its last worker during shutdown. Call after
    /// `close` so no push can land behind the drain.
    fn drain_reject(&self, err: ReplyError) {
        for shard in &self.shards {
            let drained: Vec<Request> = {
                let mut g = lock_clean(&shard.inner);
                let mut v = Vec::new();
                for lane in g.lanes.iter_mut() {
                    v.extend(lane.drain(..));
                }
                v
            };
            for req in drained {
                self.class_queued[req.class].fetch_sub(1, Ordering::SeqCst);
                let _ = req.respond.send(Err(err.clone()));
            }
        }
    }

    /// Drain up to `max` requests of one class from one shard: the lane
    /// whose head has waited longest wins (FIFO-fair across tenants).
    fn try_take(&self, idx: usize, max: usize) -> Option<(Vec<Request>, usize)> {
        let mut g = lock_clean(&self.shards[idx].inner);
        let class = oldest_lane(&g.lanes)?;
        let lane = &mut g.lanes[class];
        let take = max.min(lane.len());
        let taken: Vec<Request> = lane.drain(..take).collect();
        drop(g);
        self.class_queued[class].fetch_sub(take, Ordering::SeqCst);
        Some((taken, class))
    }

    /// Dynamic batcher: block for the first request — home shard first,
    /// then steal from siblings — returning `None` once the queue is
    /// closed *and* globally drained (the worker-exit signal). After the
    /// first take, drains same-class arrivals on the home shard for up to
    /// `timeout`, **capped at the earliest deadline already in the batch**
    /// (minus [`DEADLINE_FILL_MARGIN`]) and skipped entirely when nothing
    /// is queued anywhere and another worker is already parked — holding a
    /// lone request to "fill" a batch that has no other source is exactly
    /// the deadline-blind bug this replaces. Returns the batch, the global
    /// depth left behind, and the batch's tenant class.
    fn pop_batch(
        &self,
        home: usize,
        max: usize,
        timeout: Duration,
    ) -> Option<(Vec<Request>, usize, usize)> {
        let nshards = self.shards.len();
        let home = home % nshards;
        // Phase 1: acquire the first request(s), parking on the home
        // condvar when every shard is empty.
        let (mut batch, class) = 'first: loop {
            if let Some(t) = self.try_take(home, max) {
                break 'first t;
            }
            for k in 1..nshards {
                if let Some(t) = self.try_take((home + k) % nshards, max) {
                    break 'first t;
                }
            }
            let shard = &self.shards[home];
            let mut g = lock_clean(&shard.inner);
            loop {
                if g.lanes.iter().any(|q| !q.is_empty()) {
                    break; // re-check home under its lock before parking
                }
                if g.closed {
                    if self.len() == 0 {
                        return None;
                    }
                    break; // closed but a sibling still holds work: steal it
                }
                self.idle_workers.fetch_add(1, Ordering::Relaxed);
                if self.timed_park() {
                    let (g2, timed_out) = wait_timeout_clean(&shard.cv, g, STEAL_POLL);
                    g = g2;
                    self.idle_workers.fetch_sub(1, Ordering::Relaxed);
                    if timed_out {
                        break; // go retry the steal sweep
                    }
                } else {
                    g = wait_clean(&shard.cv, g);
                    self.idle_workers.fetch_sub(1, Ordering::Relaxed);
                }
            }
        };
        // Phase 2: deadline-aware fill-wait on the home shard. The wait cap
        // is re-derived each iteration from the earliest in-batch deadline
        // so a tight-deadline arrival mid-wait shortens the remaining wait.
        if batch.len() < max {
            let fill_until = Instant::now() + timeout;
            let skip = self.len() == 0 && self.idle_workers.load(Ordering::Relaxed) > 0;
            if !skip {
                let shard = &self.shards[home];
                let mut g = lock_clean(&shard.inner);
                loop {
                    let mut took = 0usize;
                    while batch.len() < max {
                        match g.lanes[class].pop_front() {
                            Some(r) => {
                                batch.push(r);
                                took += 1;
                            }
                            None => break,
                        }
                    }
                    if took > 0 {
                        self.class_queued[class].fetch_sub(took, Ordering::SeqCst);
                    }
                    if batch.len() >= max || g.closed {
                        break;
                    }
                    // A foreign-class arrival may have consumed our wakeup
                    // token; pass it along so a parked sibling serves it.
                    if g.lanes.iter().enumerate().any(|(i, q)| i != class && !q.is_empty()) {
                        shard.cv.notify_one();
                    }
                    let now = Instant::now();
                    let cap_at = batch
                        .iter()
                        .filter_map(|r| r.deadline)
                        .min()
                        .map(|d| d.checked_sub(DEADLINE_FILL_MARGIN).unwrap_or(now))
                        .map_or(fill_until, |d| d.min(fill_until));
                    let left = cap_at.saturating_duration_since(now);
                    if left.is_zero() {
                        break;
                    }
                    let (g2, _timed_out) = wait_timeout_clean(&shard.cv, g, left);
                    g = g2;
                }
            }
        }
        let depth = self.len();
        Some((batch, depth, class))
    }
}

/// Shutdown/supervision flags shared between the service handle, the
/// supervisor thread and the workers' [`AliveGuard`]s.
#[derive(Default)]
struct SupervisorState {
    /// Set by `close`-with-intent-to-stop (`shutdown` / `Drop`): the
    /// supervisor stops respawning once the queue is drained.
    stopping: AtomicBool,
    /// Set by the supervisor on exit, after the terminal queue drain — the
    /// point past which a submit can never be answered.
    done: AtomicBool,
}

/// Decrements the live-worker count on scope exit — including a panic
/// unwind. While the service is running, a dead pool is the **supervisor's**
/// problem (it respawns); only during shutdown, when the last worker exits
/// with the supervisor no longer respawning, does the guard close and drain
/// the queue so no `Pending::wait` can block forever.
struct AliveGuard {
    alive: Arc<AtomicUsize>,
    queue: Arc<ShardedQueue>,
    sup: Arc<SupervisorState>,
}

impl Drop for AliveGuard {
    fn drop(&mut self) {
        if self.alive.fetch_sub(1, Ordering::SeqCst) == 1
            && self.sup.stopping.load(Ordering::SeqCst)
        {
            self.queue.close();
            self.queue.drain_reject(ReplyError::Closed);
        }
    }
}

/// Per-tenant hot-swap surface: each class carries its own
/// [`PolicySwitch`] (so a governor stepping one tenant's ladder never
/// moves another tenant's rung) and its own epoch → [`PowerModel`] map so
/// energy accounting follows the rung that actually served the batch.
/// Every class starts on the service's start policy as generation 0.
struct ClassPlane {
    switch: Arc<PolicySwitch>,
    powers: Arc<Mutex<HashMap<u64, PowerModel>>>,
}

/// Everything a pool worker shares with its siblings (one `Arc` bundle per
/// worker instead of a parameter per handle). The policy half is the
/// hot-swap surface: the batch's class plane `switch` is loaded once per
/// batch, its `powers` maps each installed epoch to its precomputed
/// [`PowerModel`]. The fault half is the chaos surface: `faults` (when
/// attached) draws the per-batch injection schedule, `monitor` bands the
/// live CV residual, `batch_seq` numbers batches pool-wide — shard- and
/// class-agnostic, so a chaos schedule addresses sharded pools exactly
/// like the single queue.
#[derive(Clone)]
struct WorkerShared {
    engine: Arc<Engine>,
    queue: Arc<ShardedQueue>,
    metrics: Arc<Metrics>,
    telemetry: Arc<Telemetry>,
    /// One policy plane per tenant class (index = class id).
    planes: Arc<Vec<ClassPlane>>,
    /// Uniform fallback for generations installed with `policy == None`.
    base_opts: ForwardOpts,
    base_power: PowerModel,
    alive: Arc<AtomicUsize>,
    sup: Arc<SupervisorState>,
    faults: Option<Arc<FaultPlan>>,
    monitor: IntegrityMonitor,
    batch_seq: Arc<AtomicU64>,
}

impl WorkerShared {
    /// Resolve the forward configuration for one batch from a captured
    /// generation. The CV-proxy sampler is attached per batch in
    /// `run_batch` (batch-local, folded into shared telemetry only once
    /// the batch is trusted), not here.
    fn resolve_opts(&self, stamped: &StampedPolicy) -> ForwardOpts {
        match &stamped.policy {
            Some(p) => ForwardOpts::with_policy(p.clone()),
            None => self.base_opts.clone(),
        }
    }

    /// Power model for a captured generation of one class, memoized per
    /// worker: epochs change at governor-dwell cadence (hundreds of ms),
    /// so the class's shared `powers` lock is only touched when that
    /// class's epoch actually moved — the steady-state batch path never
    /// contends on it.
    fn resolve_power<'c>(
        &self,
        class: usize,
        stamped: &StampedPolicy,
        cache: &'c mut (u64, PowerModel),
    ) -> &'c PowerModel {
        if cache.0 != stamped.epoch {
            let power = self
                .planes
                .get(class)
                .map(|plane| {
                    lock_clean(&plane.powers)
                        .get(&stamped.epoch)
                        .cloned()
                        .unwrap_or_else(|| self.base_power.clone())
                })
                .unwrap_or_else(|| self.base_power.clone());
            *cache = (stamped.epoch, power);
        }
        &cache.1
    }
}

/// Cloneable hot-swap handle into a running pool: validates, **warms** and
/// atomically installs per-layer policies without owning the service (what
/// the QoS governor holds). Warming happens before the swap — the new
/// generation's `LayerPlan`s are built into the shared cache while the pool
/// still serves the old one, so a swap never stalls a worker on a plan
/// build (steady-state swaps between previously seen rungs are pure cache
/// hits).
#[derive(Clone)]
pub struct PolicyInstaller {
    engine: Arc<Engine>,
    switch: Arc<PolicySwitch>,
    powers: Arc<Mutex<HashMap<u64, PowerModel>>>,
    n_array: u32,
}

/// Epochs of power-model history kept for in-flight batches; a governed
/// service installs a new generation per dwell, so without a cap the map
/// would grow without bound. A batch only ever looks up the epoch it
/// captured at pop time, which is always among the most recent handful
/// (evicted epochs fall back to the start generation's power model).
const POWER_EPOCHS_KEPT: usize = 64;

impl PolicyInstaller {
    /// Install `policy` as the next generation; returns its epoch. Errors
    /// (layer-count mismatch) leave the current generation serving.
    pub fn install(&self, policy: SharedPolicy) -> Result<u64> {
        policy.validate_for(&self.engine.model).context("install policy")?;
        self.engine.prepare_plans_policy(&policy).context("install policy")?;
        let power = PowerModel::for_policy(&policy, &self.engine.model, self.n_array);
        // Publish under the powers lock so a worker that loads the fresh
        // epoch and immediately looks up its power blocks on this lock
        // instead of falling back to the base model.
        let mut powers = lock_clean(&self.powers);
        let epoch = self.switch.install(Some(policy));
        powers.insert(epoch, power);
        while powers.len() > POWER_EPOCHS_KEPT {
            let Some(&oldest) = powers.keys().min() else { break };
            powers.remove(&oldest);
        }
        Ok(epoch)
    }

    /// Epoch of the currently serving generation.
    pub fn epoch(&self) -> u64 {
        self.switch.epoch()
    }

    /// The served model (ladder validation).
    pub fn model(&self) -> &Model {
        &self.engine.model
    }
}

/// A running inference service: a supervised worker pool over one shared
/// engine.
pub struct InferenceService {
    queue: Arc<ShardedQueue>,
    /// Live worker handles; shared with the supervisor, which reaps crashed
    /// entries and pushes respawned ones.
    handles: Arc<Mutex<Vec<JoinHandle<()>>>>,
    supervisor: Option<JoinHandle<()>>,
    sup: Arc<SupervisorState>,
    alive: Arc<AtomicUsize>,
    engine: Arc<Engine>,
    planes: Arc<Vec<ClassPlane>>,
    /// Resolved tenant classes (index = class id; 0 = default tenant).
    tenants: Vec<TenantClass>,
    n_array: u32,
    pub metrics: Arc<Metrics>,
    /// Power model of the generation the service STARTED with (epoch 0);
    /// per-request energy accounting follows the serving epoch.
    pub power: PowerModel,
    /// Live serving telemetry (latency ring, queue depth, batch occupancy,
    /// CV error proxy) — what the QoS governor polls.
    pub telemetry: Arc<Telemetry>,
}

impl InferenceService {
    /// Start the service over a prepared engine.
    ///
    /// Fails — before any worker thread spawns, so there is no pool to
    /// poison — when the effective per-layer policy (from
    /// `ServiceConfig::policy` or the `CVAPPROX_SERVICE_POLICY` file) does
    /// not parse or does not match the model's MAC layer count.
    pub fn start(engine: Engine, cfg: ServiceConfig) -> Result<InferenceService> {
        let policy = resolve_policy(
            cfg.policy.as_ref(),
            std::env::var("CVAPPROX_SERVICE_POLICY").ok().as_deref(),
        )?;
        // Resolve tenant classes: explicit config wins, else the
        // CVAPPROX_TENANT_CLASSES spec, else one default class carrying the
        // legacy queue_cap. A malformed spec fails here, before any thread.
        let tenants: Vec<TenantClass> = if !cfg.tenants.is_empty() {
            cfg.tenants.clone()
        } else {
            match std::env::var("CVAPPROX_TENANT_CLASSES") {
                Ok(spec) if !spec.trim().is_empty() => parse_tenant_spec(&spec)?,
                _ => {
                    let mut class = TenantClass::new("default");
                    class.queue_cap = cfg.queue_cap;
                    vec![class]
                }
            }
        };
        let n_workers = cfg.workers.max(1);
        let shards = resolve_shards(cfg.shards, n_workers);
        let metrics = Arc::new(Metrics::new());
        let queue = Arc::new(ShardedQueue::new(
            shards,
            tenants.iter().map(|t| t.queue_cap).collect(),
        ));
        let telemetry = Arc::new(Telemetry::with_classes(
            tenants.len(),
            crate::qos::telemetry::DEFAULT_WINDOW,
            engine.model.mac_layers(),
        ));
        // Warm the weight-side plans once, before any worker spawns: the
        // pool shares one PlanCache through the Arc'd engine, so no request
        // on any worker pays the one-time build. With a policy, each layer
        // is warmed at its own point — and the layer-count validation
        // happens here, turning a bad policy into a start-time `Err`.
        let (power, base_opts) = match &policy {
            Some(p) => {
                p.validate_for(&engine.model).context("service policy")?;
                engine.prepare_plans_policy(p).context("service policy")?;
                (
                    PowerModel::for_policy(p, &engine.model, cfg.n_array),
                    ForwardOpts::with_policy(p.clone()),
                )
            }
            None => {
                // Uniform serving gets the same start-time gate as a policy:
                // an out-of-range m or an oversized-K layer (i32-headroom,
                // e.g. positive polarity above MAX_K_POS) is a typed error
                // here, never a worker panic mid-batch.
                let opts = ForwardOpts::approx(cfg.family, cfg.m, cfg.use_cv);
                engine.validate_opts(&opts).context("service config")?;
                engine.prepare_plans(cfg.family, cfg.m);
                (PowerModel::new(cfg.family, cfg.m, cfg.n_array), opts)
            }
        };
        // Generation 0 is the start configuration; every tenant class gets
        // its own policy plane seeded with it, so per-class governors can
        // step their ladders independently from the same origin.
        let planes: Arc<Vec<ClassPlane>> = Arc::new(
            (0..tenants.len())
                .map(|_| ClassPlane {
                    switch: Arc::new(PolicySwitch::new(policy.clone())),
                    powers: Arc::new(Mutex::new(HashMap::from([(0u64, power.clone())]))),
                })
                .collect(),
        );
        // Anchor the throughput clock at "service ready" — after the plan
        // warm-up, so the one-time build does not deflate throughput /
        // occupancy, but before any request can complete, so even a
        // one-request session reports a rate. Also size the per-worker
        // counters for the whole pool so idle workers show up as zeros,
        // and name the per-class rows.
        metrics.mark_started();
        metrics.init_workers(n_workers);
        metrics.init_classes(&tenants.iter().map(|t| t.name.clone()).collect::<Vec<_>>());
        let engine = Arc::new(engine);
        let alive = Arc::new(AtomicUsize::new(0));
        let sup = Arc::new(SupervisorState::default());
        let faults = cfg.faults.clone().map(|c| Arc::new(FaultPlan::new(c)));
        let shared = WorkerShared {
            engine: engine.clone(),
            queue: queue.clone(),
            metrics: metrics.clone(),
            telemetry: telemetry.clone(),
            planes: planes.clone(),
            base_opts,
            base_power: power.clone(),
            alive: alive.clone(),
            sup: sup.clone(),
            faults,
            monitor: IntegrityMonitor::new(),
            batch_seq: Arc::new(AtomicU64::new(0)),
        };
        let mut spawned: Vec<JoinHandle<()>> = Vec::with_capacity(n_workers);
        for id in 0..n_workers {
            match spawn_worker(id, &shared, &cfg) {
                Ok(h) => spawned.push(h),
                Err(e) => {
                    // Startup must not leak live threads: release the
                    // already-spawned workers (the queue is still empty, so
                    // close() lets pop_batch return None) and surface a
                    // typed error instead of panicking mid-construction.
                    sup.stopping.store(true, Ordering::SeqCst);
                    queue.close();
                    for h in spawned {
                        let _ = h.join();
                    }
                    sup.done.store(true, Ordering::SeqCst);
                    return Err(e).context("spawning service worker");
                }
            }
        }
        let handles = Arc::new(Mutex::new(spawned));
        let next_id = Arc::new(AtomicUsize::new(n_workers));
        let supervisor = {
            let shared = shared.clone();
            let cfg2 = cfg.clone();
            let handles2 = handles.clone();
            let spawn = std::thread::Builder::new()
                .name("cvapprox-supervisor".to_string())
                .spawn(move || supervisor_loop(shared, cfg2, handles2, next_id));
            match spawn {
                Ok(h) => h,
                Err(e) => {
                    sup.stopping.store(true, Ordering::SeqCst);
                    queue.close();
                    for h in lock_clean(&handles).drain(..) {
                        let _ = h.join();
                    }
                    sup.done.store(true, Ordering::SeqCst);
                    return Err(e).context("spawning service supervisor");
                }
            }
        };
        Ok(InferenceService {
            queue,
            handles,
            supervisor: Some(supervisor),
            sup,
            alive,
            engine,
            planes,
            tenants,
            n_array: cfg.n_array,
            metrics,
            power,
            telemetry,
        })
    }

    /// The resolved tenant classes (index = class id; 0 = default).
    pub fn tenants(&self) -> &[TenantClass] {
        &self.tenants
    }

    /// Number of queue shards this pool resolved to (explicit config >
    /// `CVAPPROX_SHARDS` > one per worker, clamped to the worker count).
    pub fn n_shards(&self) -> usize {
        self.queue.n_shards()
    }

    /// Hot-swap handle for the default tenant (see [`PolicyInstaller`]).
    pub fn installer(&self) -> PolicyInstaller {
        self.installer_for(0).unwrap_or_else(|| PolicyInstaller {
            engine: self.engine.clone(),
            switch: Arc::new(PolicySwitch::new(None)),
            powers: Arc::new(Mutex::new(HashMap::new())),
            n_array: self.n_array,
        })
    }

    /// Hot-swap handle for one tenant class: what that class's QoS
    /// governor holds. `None` for an out-of-range class id.
    pub fn installer_for(&self, class: usize) -> Option<PolicyInstaller> {
        self.planes.get(class).map(|plane| PolicyInstaller {
            engine: self.engine.clone(),
            switch: plane.switch.clone(),
            powers: plane.powers.clone(),
            n_array: self.n_array,
        })
    }

    /// Validate, warm and atomically install a new per-layer policy for
    /// the default tenant; new batches serve it immediately, in-flight
    /// batches complete on their captured generation. Returns the new
    /// epoch.
    pub fn install_policy(&self, policy: SharedPolicy) -> Result<u64> {
        self.installer().install(policy)
    }

    /// Install a policy into one tenant class's plane (other classes are
    /// untouched — the per-tenant isolation anchor).
    pub fn install_policy_for(&self, class: usize, policy: SharedPolicy) -> Result<u64> {
        match self.installer_for(class) {
            Some(installer) => installer.install(policy),
            None => anyhow::bail!("unknown tenant class {class}"),
        }
    }

    /// Epoch of the default tenant's currently serving generation.
    pub fn current_epoch(&self) -> u64 {
        self.current_epoch_for(0)
    }

    /// Epoch of one tenant class's serving generation (0 for an unknown
    /// class — epoch 0 is the start generation every class began on).
    pub fn current_epoch_for(&self, class: usize) -> u64 {
        self.planes.get(class).map_or(0, |plane| plane.switch.epoch())
    }

    /// The shared engine: integrity probes (`verify_integrity`,
    /// `integrity_generation`) and targeted corruption (`corrupt_lut` /
    /// `corrupt_plan`) for chaos tests and the chaos bench.
    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    /// Live queue-depth probe the QoS governor polls at decision time: a
    /// saturated pool whose in-flight batches outlast a whole decision
    /// window completes nothing — indistinguishable from idle on the
    /// drained telemetry alone — but its backlog is visible here (queued
    /// work) and in `Telemetry::in_flight` (popped work), and together
    /// they keep the governor from "recovering" toward exact in the middle
    /// of that overload. One cheap lock per decision, not per batch.
    pub fn depth_probe(&self) -> Arc<dyn Fn() -> usize + Send + Sync> {
        let queue = self.queue.clone();
        Arc::new(move || queue.len())
    }

    /// Per-class queue-depth probe — what each tenant's governor polls, so
    /// one tenant's backlog never reads as another's load.
    pub fn class_depth_probe(&self, class: usize) -> Arc<dyn Fn() -> usize + Send + Sync> {
        let queue = self.queue.clone();
        Arc::new(move || queue.class_len(class))
    }

    /// Submit an image with typed rejection: `Err(Closed)` after shutdown,
    /// `Err(Overloaded)` when the class's bounded queue is full (counted in
    /// `MetricsSnapshot::rejected_overload`). Never panics, never hangs.
    ///
    /// A momentarily empty pool (every worker crashed at once) is NOT
    /// `Closed`: the supervisor is respawning, the queue is open, and the
    /// request will be served — only a finished supervisor is terminal.
    pub fn try_submit(
        &self,
        image: Tensor,
        deadline: Option<Instant>,
    ) -> std::result::Result<Pending, ReplyError> {
        self.try_submit_for(0, image, deadline)
    }

    /// Submit for one tenant class. An unknown class id is a typed
    /// `BadInput` (never a panic); a `None` deadline picks up the class's
    /// [`TenantClass::default_deadline`].
    pub fn try_submit_for(
        &self,
        class: usize,
        image: Tensor,
        deadline: Option<Instant>,
    ) -> std::result::Result<Pending, ReplyError> {
        if class >= self.tenants.len() {
            return Err(ReplyError::BadInput(format!(
                "unknown tenant class {class} (service has {})",
                self.tenants.len()
            )));
        }
        if self.alive.load(Ordering::SeqCst) == 0 && self.sup.done.load(Ordering::SeqCst) {
            return Err(ReplyError::Closed);
        }
        let enqueued = Instant::now();
        let deadline = deadline.or_else(|| {
            self.tenants
                .get(class)
                .and_then(|t| t.default_deadline)
                .map(|budget| enqueued + budget)
        });
        let (rtx, rrx) = mpsc::sync_channel(1);
        let req = Request { image, enqueued, deadline, class, respond: rtx };
        match self.queue.push(req) {
            Ok(()) => Ok(Pending { rx: rrx }),
            Err((_req, e)) => {
                if e == ReplyError::Overloaded {
                    self.metrics.record_overload_for(class);
                }
                Err(e)
            }
        }
    }

    /// Submit an image; returns a handle to wait on, or `Err` when the
    /// service is shut down / over capacity (never panics).
    pub fn submit(&self, image: Tensor) -> Result<Pending> {
        self.try_submit(image, None).map_err(anyhow::Error::from)
    }

    /// Submit for one tenant class (see [`InferenceService::try_submit_for`]).
    pub fn submit_for(&self, class: usize, image: Tensor) -> Result<Pending> {
        self.try_submit_for(class, image, None).map_err(anyhow::Error::from)
    }

    /// Submit with a latency budget: the request is answered
    /// `Err(Deadline)` if no worker dequeues it within `budget`.
    pub fn submit_with_deadline(
        &self,
        image: Tensor,
        budget: Duration,
    ) -> std::result::Result<Pending, ReplyError> {
        self.try_submit(image, Some(Instant::now() + budget))
    }

    /// Submit for one tenant class with an explicit latency budget.
    pub fn submit_with_deadline_for(
        &self,
        class: usize,
        image: Tensor,
        budget: Duration,
    ) -> std::result::Result<Pending, ReplyError> {
        self.try_submit_for(class, image, Some(Instant::now() + budget))
    }

    /// Submit and wait (convenience).
    pub fn infer(&self, image: Tensor) -> Result<Reply> {
        self.submit(image)?.wait()
    }

    /// Submit-and-wait with client-side retry: transient failures
    /// ([`ReplyError::retryable`] — overload, worker crash) are retried up
    /// to `attempts` times under exponential backoff starting at
    /// `base_backoff`; terminal errors return immediately.
    pub fn infer_with_retry(
        &self,
        image: &Tensor,
        attempts: usize,
        base_backoff: Duration,
    ) -> std::result::Result<Reply, ReplyError> {
        let mut backoff = Backoff::new(base_backoff, base_backoff * 16);
        retry(attempts, &mut backoff, ReplyError::retryable, || {
            self.try_submit(image.clone(), None)?.wait_reply()
        })
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Stop accepting new requests; already-queued work still drains.
    /// Subsequent `submit`/`infer` calls return `Err`. Idempotent.
    pub fn close(&self) {
        self.queue.close();
    }

    /// Drain queued work, stop the pool, and return the final metrics.
    pub fn shutdown(mut self) -> MetricsSnapshot {
        self.stop_and_join();
        self.metrics.snapshot()
    }

    fn stop_and_join(&mut self) {
        self.sup.stopping.store(true, Ordering::SeqCst);
        self.queue.close();
        if let Some(h) = self.supervisor.take() {
            let _ = h.join();
        }
        let drained: Vec<JoinHandle<()>> = lock_clean(&self.handles).drain(..).collect();
        for h in drained {
            let _ = h.join();
        }
    }
}

impl Drop for InferenceService {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Register a worker as alive (on the caller's thread, so `start` returns
/// with the count already correct) and spawn its serving thread. On spawn
/// failure (thread exhaustion) the census is rolled back and the error
/// returned for the caller to handle — `start` fails typed, the
/// supervisor retries on a later tick.
fn spawn_worker(
    id: usize,
    shared: &WorkerShared,
    cfg: &ServiceConfig,
) -> std::io::Result<JoinHandle<()>> {
    shared.alive.fetch_add(1, Ordering::SeqCst);
    let shared2 = shared.clone();
    let cfg = cfg.clone();
    let spawned = std::thread::Builder::new()
        .name(format!("cvapprox-worker-{id}"))
        .spawn(move || worker_loop(id, shared2, cfg));
    if spawned.is_err() {
        shared.alive.fetch_sub(1, Ordering::SeqCst);
    }
    spawned
}

/// Supervisor poll cadence; also bounds how long shutdown lags the last
/// worker exit.
const SUPERVISOR_TICK: Duration = Duration::from_millis(1);

/// The supervision loop: reap finished worker threads and — while the
/// service still has work to serve — respawn replacements (fresh id, fresh
/// scratch) under exponential backoff, so a crash-looping fault cannot
/// busy-spin the pool. On the way out (stop requested, queue drained, all
/// workers joined) it closes and terminally drains the queue: after `done`
/// is set, no accepted request can still be unanswered.
fn supervisor_loop(
    shared: WorkerShared,
    cfg: ServiceConfig,
    handles: Arc<Mutex<Vec<JoinHandle<()>>>>,
    next_id: Arc<AtomicUsize>,
) {
    let mut backoff = Backoff::new(Duration::from_millis(1), Duration::from_millis(50));
    // Workers reaped but not yet successfully replaced. Kept across ticks
    // so a failed respawn (thread exhaustion) shrinks the pool only until
    // the next tick, not permanently.
    let mut deficit = 0usize;
    loop {
        let stopping = shared.sup.stopping.load(Ordering::SeqCst);
        let mut reaped = 0usize;
        {
            let mut hs = lock_clean(&handles);
            let mut i = 0;
            while i < hs.len() {
                if hs[i].is_finished() {
                    let h = hs.swap_remove(i);
                    let _ = h.join();
                    reaped += 1;
                } else {
                    i += 1;
                }
            }
        }
        // Respawn while the service is open for business, or while queued
        // requests still need a worker to drain them (a crash during
        // shutdown must not strand the queue).
        let must_serve = (!stopping && !shared.queue.is_closed()) || shared.queue.len() > 0;
        deficit += reaped;
        if deficit > 0 && must_serve {
            std::thread::sleep(backoff.next_delay());
            while deficit > 0 {
                let id = next_id.fetch_add(1, Ordering::SeqCst);
                match spawn_worker(id, &shared, &cfg) {
                    Ok(h) => {
                        shared.metrics.record_worker_restart();
                        lock_clean(&handles).push(h);
                        deficit -= 1;
                    }
                    // Spawn failure: keep the deficit and retry next tick
                    // under the same backoff that paces crash respawns.
                    Err(_) => break,
                }
            }
        } else if deficit == 0 {
            backoff.reset();
        }
        if stopping && lock_clean(&handles).is_empty() {
            break;
        }
        std::thread::sleep(SUPERVISOR_TICK);
    }
    // Terminal drain: everything still queued (e.g. submitted in the close
    // race window) gets a typed answer before `done` flips.
    shared.queue.close();
    shared.queue.drain_reject(ReplyError::Closed);
    shared.sup.done.store(true, Ordering::SeqCst);
}

/// Batches between periodic full checksum sweeps in production mode
/// (no fault plan attached). Chaos mode verifies every batch instead.
const INTEGRITY_SWEEP_BATCHES: u64 = 64;

/// Forward attempts per batch: 1 initial + replays after heals. Corruption
/// arriving faster than once per attempt for this many attempts is a
/// persistent fault — answered as [`ReplyError::Integrity`], never served
/// silently wrong.
const MAX_BATCH_ATTEMPTS: usize = 4;

fn worker_loop(worker_id: usize, shared: WorkerShared, cfg: ServiceConfig) {
    let _guard = AliveGuard {
        alive: shared.alive.clone(),
        queue: shared.queue.clone(),
        sup: shared.sup.clone(),
    };
    let macs = shared.engine.model.macs();
    let mac_layers = shared.engine.model.mac_layers();
    let input_shape = shared.engine.model.input_shape();
    // One scratch arena per worker, pre-grown to the model's worst-case
    // GEMM footprint at this batch size, so steady-state batches allocate
    // nothing on the GEMM path.
    let batch_cap = cfg.batch_size.max(1);
    let mut scratch = Scratch::new();
    let (panel, acc) = shared.engine.model.max_gemm_footprint();
    scratch.reserve(panel * batch_cap, acc * batch_cap);
    // Per-worker, per-class (epoch → power) memo: every class starts on
    // epoch 0, the start generation.
    let mut power_caches: Vec<(u64, PowerModel)> =
        vec![(0, shared.base_power.clone()); shared.planes.len()];
    // Home shard: worker groups map onto shards round-robin, so respawned
    // workers (monotonic ids) keep the shard coverage balanced.
    let home = worker_id % shared.queue.n_shards();
    while let Some((batch, depth, class)) = shared.queue.pop_batch(home, batch_cap, cfg.batch_timeout)
    {
        if batch.is_empty() {
            continue;
        }
        // Admission screens, cheapest first: expired deadlines (client has
        // given up — don't spend a batch slot), then malformed images (one
        // bad request cannot poison the whole batched forward).
        let now = Instant::now();
        let mut expired = 0usize;
        let mut good: Vec<Request> = Vec::with_capacity(batch.len());
        for req in batch {
            if req.deadline.is_some_and(|d| now > d) {
                shared.metrics.record_deadline_expired_for(class);
                expired += 1;
                let _ = req.respond.send(Err(ReplyError::Deadline));
                continue;
            }
            let t = &req.image;
            if (t.h, t.w, t.c) == input_shape {
                good.push(req);
            } else {
                let msg = format!(
                    "input shape mismatch: got {}x{}x{}, model expects {}x{}x{}",
                    t.h, t.w, t.c, input_shape.0, input_shape.1, input_shape.2
                );
                let _ = req.respond.send(Err(ReplyError::BadInput(msg)));
            }
        }
        if expired > 0 {
            // Screened-out requests never executed: count them in their own
            // telemetry column instead of letting them inflate (or silently
            // vanish from) the occupancy books — see `qos::telemetry`.
            shared.telemetry.record_expired_for(class, expired);
        }
        if good.is_empty() {
            // The pop still observed real queue pressure; record the depth
            // sample without an occupancy sample (nothing executed).
            shared.telemetry.record_depth_for(class, depth);
            continue;
        }
        // The ledger owns the batch's requests across the panic boundary:
        // whatever `run_batch` has not answered when it unwinds is still in
        // here, and each entry gets a typed `WorkerCrashed` before the
        // thread retires — the exactly-one-reply invariant survives the
        // crash.
        let ledger = Mutex::new(good);
        let run = catch_unwind(AssertUnwindSafe(|| {
            run_batch(
                worker_id,
                class,
                &shared,
                &ledger,
                &mut scratch,
                &mut power_caches,
                macs,
                mac_layers,
                batch_cap,
                depth,
            )
        }));
        if run.is_err() {
            let stranded = ledger.into_inner().unwrap_or_else(|e| e.into_inner());
            shared.metrics.record_crashed_replies(stranded.len());
            for req in stranded {
                let _ = req.respond.send(Err(ReplyError::WorkerCrashed));
            }
            // Retire: scratch and caches may be mid-mutation; the
            // supervisor respawns a clean replacement.
            return;
        }
    }
}

/// Records batch-level metrics on scope exit so the books stay balanced
/// even when the batch unwinds mid-forward (the in-flight gauge raised by
/// `batch_started` must always come back down).
struct BatchGauge<'a> {
    shared: &'a WorkerShared,
    worker_id: usize,
    class: usize,
    n: usize,
    cap: usize,
    depth: usize,
    t0: Instant,
}

impl Drop for BatchGauge<'_> {
    fn drop(&mut self) {
        self.shared.metrics.record_batch(self.worker_id, self.n, self.t0.elapsed());
        self.shared.telemetry.record_batch_for(self.class, self.n, self.cap, self.depth);
    }
}

/// Serve one admitted batch: inject this batch's scheduled faults (chaos
/// mode only), run the fused forward under the integrity loop — CV-band
/// alarm → checksum arbitration → heal → replay — and answer every request
/// in the ledger exactly once.
#[allow(clippy::too_many_arguments)]
fn run_batch(
    worker_id: usize,
    class: usize,
    shared: &WorkerShared,
    ledger: &Mutex<Vec<Request>>,
    scratch: &mut Scratch,
    power_caches: &mut [(u64, PowerModel)],
    macs: u64,
    mac_layers: usize,
    batch_cap: usize,
    depth: usize,
) {
    // Draw this batch's fault decision first: the corruption lands in the
    // shared caches (where a real SRAM upset would) *before* the forward
    // that must detect it.
    let faults = match &shared.faults {
        Some(plan) => plan.next_batch(),
        None => BatchFaults::default(),
    };
    let seq = shared.batch_seq.fetch_add(1, Ordering::Relaxed);
    if faults.any() {
        let mut injected = 0usize;
        if let Some(f) = faults.lut {
            if shared.engine.corrupt_lut(f.pick, f.entry, f.span, f.bit).is_some() {
                injected += 1;
            }
        }
        if let Some(f) = faults.plan {
            if shared.engine.corrupt_plan(f.pick, f.byte, f.bit).is_some() {
                injected += 1;
            }
        }
        injected += usize::from(faults.panic)
            + usize::from(faults.spike.is_some())
            + usize::from(faults.drop_replies);
        if injected > 0 {
            shared.metrics.record_injected_faults(injected);
        }
        if let Some(d) = faults.spike {
            std::thread::sleep(d);
        }
        if faults.panic {
            // srclint: allow(R3, chaos injection must unwind for real so the ledger sweep + supervisor respawn path is exercised)
            panic!("injected worker panic (chaos schedule)");
        }
    }
    // Capture the batch class's policy generation ONCE per batch: the
    // whole batch runs under this epoch's policy (a concurrent install
    // affects only later batches of this class, and other classes' planes
    // are untouched), which is exactly the per-tenant hot-swap consistency
    // invariant the property tests pin.
    let stamped = match shared.planes.get(class) {
        Some(plane) => plane.switch.load(),
        None => return, // unreachable: submits validate the class id
    };
    let mut opts = shared.resolve_opts(&stamped);
    // Batch-local CV sampler: its sums become the batch's integrity
    // signature AND — only once the batch is trusted — the governor's
    // telemetry. Replayed (corrupt) attempts drain into the void.
    let local = Arc::new(CvProxySampler::new(mac_layers));
    opts.cv_proxy = Some(local.clone());
    let mut fallback = (0u64, shared.base_power.clone());
    let cache = power_caches.get_mut(class).unwrap_or(&mut fallback);
    let power = shared.resolve_power(class, &stamped, cache).clone();
    let mut requests = lock_clean(ledger);
    let n = requests.len();
    // Raise the in-flight gauge before the forward: requests inside an
    // executing batch are visible to neither the queue depth nor the
    // completion count, and the governor must not mistake a pool
    // saturated by long batches for an idle one.
    shared.telemetry.batch_started_for(class, n);
    let t0 = Instant::now();
    let _gauge = BatchGauge { shared, worker_id, class, n, cap: batch_cap, depth, t0 };
    let chaos = shared.faults.is_some();
    let sweep_due = seq % INTEGRITY_SWEEP_BATCHES == 0;
    let mut outcome = None;
    let mut forward_err = None;
    for _attempt in 0..MAX_BATCH_ATTEMPTS {
        let gen0 = shared.engine.integrity_generation();
        let result = {
            let imgs: Vec<&Tensor> = requests.iter().map(|r| &r.image).collect();
            shared.engine.forward_batch_with_scratch(&imgs, &opts, scratch)
        };
        let raw = local.drain_raw();
        let all_logits = match result {
            Ok(v) => v,
            Err(e) => {
                forward_err = Some(e);
                break;
            }
        };
        // CV-residual band check: the paper's accuracy mechanism doubling
        // as a corruption detector — a flipped high bit in a hot LUT
        // stripe blows the live mean |V|/|G*| orders of magnitude out of
        // its offline signed-moment band. The checksum pass arbitrates
        // every alarm, so a band false positive costs one verify sweep,
        // never a replay.
        let alarm = !shared.monitor.suspects(&raw, |i| opts.assignment_for(i)).is_empty();
        if alarm {
            shared.metrics.record_integrity_alarm();
        }
        if chaos || sweep_due || alarm {
            let report = shared.engine.verify_integrity();
            if !report.is_clean() {
                shared.metrics.record_heal(shared.engine.heal_integrity());
                shared.metrics.record_replay();
                continue;
            }
        }
        if shared.engine.integrity_generation() != gen0 {
            // Cache state moved under this forward (a sibling healed or
            // chaos corrupted mid-batch): the logits may have read
            // poisoned panels — recompute on the now-stable state.
            shared.metrics.record_replay();
            continue;
        }
        outcome = Some((all_logits, raw));
        break;
    }
    match (outcome, forward_err) {
        (Some((all_logits, raw)), _) => {
            // The batch is trusted: fold its CV sums into this class's
            // partition of the shared telemetry exactly once (replayed
            // attempts never pollute any governor's windows).
            shared.telemetry.record_cv_for(class, &raw);
            if faults.drop_replies {
                // Chaos "lost reply": drop every channel unanswered; each
                // client observes a disconnect, typed as `WorkerCrashed` —
                // the one injected fault clients must retry blind.
                shared.metrics.record_crashed_replies(requests.len());
                requests.clear();
                return;
            }
            for (req, logits) in requests.drain(..).zip(all_logits) {
                let queue_wait = t0.saturating_duration_since(req.enqueued);
                let latency = req.enqueued.elapsed();
                shared.metrics.record_for(class, latency, queue_wait, macs, &power);
                shared.telemetry.record_latency_for(class, latency);
                let reply = if !logits.is_empty() && logits.iter().all(|v| v.is_nan()) {
                    Err(ReplyError::BadInput(
                        "all logits are NaN (non-finite model output)".to_string(),
                    ))
                } else {
                    Ok(Reply {
                        top1: argmax(&logits),
                        logits,
                        latency,
                        epoch: stamped.epoch,
                        tenant: class,
                    })
                };
                let _ = req.respond.send(reply);
            }
        }
        (None, Some(e)) => {
            let msg = format!("batched forward failed: {e:#}");
            for req in requests.drain(..) {
                let queue_wait = t0.saturating_duration_since(req.enqueued);
                let latency = req.enqueued.elapsed();
                shared.metrics.record_for(class, latency, queue_wait, macs, &power);
                shared.telemetry.record_latency_for(class, latency);
                let _ = req.respond.send(Err(ReplyError::BadInput(msg.clone())));
            }
        }
        (None, None) => {
            // Replay budget exhausted: corruption returned faster than
            // healing for MAX_BATCH_ATTEMPTS straight attempts. Refuse
            // rather than risk serving a silently wrong answer.
            for req in requests.drain(..) {
                let queue_wait = t0.saturating_duration_since(req.enqueued);
                let latency = req.enqueued.elapsed();
                shared.metrics.record_for(class, latency, queue_wait, macs, &power);
                shared.telemetry.record_latency_for(class, latency);
                let _ = req.respond.send(Err(ReplyError::Integrity));
            }
        }
    }
}

/// Index of the largest logit. NaN-safe: a NaN never wins (it ranks below
/// every real value — the `>=` against a NEG_INFINITY start admits every
/// non-NaN, including -∞ itself), ties keep the previous
/// `Iterator::max_by` semantics (last maximal index), and all-NaN or empty
/// input returns 0 — the old implementation's `partial_cmp().unwrap()`
/// panicked the worker thread on the first NaN instead.
pub fn argmax(xs: &[f64]) -> usize {
    let mut best = 0usize;
    let mut best_v = f64::NEG_INFINITY;
    for (i, &v) in xs.iter().enumerate() {
        if v >= best_v {
            best = i;
            best_v = v;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artifacts_dir;
    use crate::nn::loader;
    use crate::nn::testutil;

    fn artifact_engine() -> Option<Engine> {
        let path = artifacts_dir().join("models/mininet_synth10.cvm");
        if !path.exists() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        Some(Engine::new(loader::load_model(&path).unwrap()))
    }

    #[test]
    fn serves_requests_and_counts_metrics() {
        let Some(engine) = artifact_engine() else { return };
        let ds = crate::datasets::Dataset::load(
            &artifacts_dir().join("data/synth10_test.cvd"),
        )
        .unwrap();
        let cfg = ServiceConfig {
            family: Family::Perforated,
            m: 2,
            use_cv: true,
            batch_size: 4,
            ..Default::default()
        };
        let svc = InferenceService::start(engine, cfg).unwrap();
        let pendings: Vec<Pending> =
            (0..8).map(|i| svc.submit(ds.image(i)).unwrap()).collect();
        let mut correct = 0;
        for (i, p) in pendings.into_iter().enumerate() {
            let reply = p.wait().unwrap();
            assert_eq!(reply.logits.len(), 10);
            if reply.top1 == ds.label(i) {
                correct += 1;
            }
        }
        let snap = svc.shutdown();
        assert_eq!(snap.completed, 8);
        assert!(snap.batches >= 1 && snap.batches <= 8);
        assert!(snap.total_macs > 0);
        assert!(snap.energy_vs_exact < 1.0); // approximate design saves power
        assert!(correct >= 4, "sanity: {correct}/8 correct");
    }

    #[test]
    fn shutdown_is_clean_with_no_requests() {
        let svc = InferenceService::start(
            Engine::new(testutil::tiny_model()),
            ServiceConfig::default(),
        ).unwrap();
        let snap = svc.shutdown();
        assert_eq!(snap.completed, 0);
    }

    #[test]
    fn worker_pool_serves_concurrent_clients_bit_identically() {
        // N client threads hammer the pool; every reply must be bit-equal
        // to a single-threaded per-image forward on an identical engine,
        // and the batch/request counters must add up across workers.
        let model = testutil::tiny_model();
        let reference = Engine::new(model.clone());
        let cfg = ServiceConfig {
            family: Family::Truncated,
            m: 6,
            use_cv: true,
            workers: 4,
            batch_size: 4,
            ..Default::default()
        };
        let svc = InferenceService::start(Engine::new(model), cfg).unwrap();
        let opts = ForwardOpts::approx(Family::Truncated, 6, true);
        let clients = 6usize;
        let per_client = 8usize;
        std::thread::scope(|s| {
            for t in 0..clients {
                let svc = &svc;
                let reference = &reference;
                let opts = &opts;
                s.spawn(move || {
                    for i in 0..per_client {
                        let img = testutil::tiny_image((t * 100 + i) as u64);
                        let reply = svc.infer(img.clone()).unwrap();
                        let want = reference.forward(&img, opts).unwrap();
                        assert_eq!(reply.logits, want, "client {t} img {i}");
                        assert_eq!(reply.top1, argmax(&want));
                    }
                });
            }
        });
        let snap = svc.shutdown();
        assert_eq!(snap.completed, (clients * per_client) as u64);
        assert!(snap.batches >= 1);
        assert_eq!(snap.worker_batches.iter().sum::<u64>(), snap.batches);
        assert_eq!(snap.worker_requests.iter().sum::<u64>(), snap.completed);
        assert!(snap.mean_batch_size >= 1.0);
        assert!(snap.throughput_rps > 0.0);
    }

    #[test]
    fn burst_is_batched_and_bit_identical() {
        // A burst submitted up front exercises true batch fusion. Pool size
        // comes from the env-driven default so the CI sweep
        // (CVAPPROX_SERVICE_WORKERS=1 / 4 in scripts/verify.sh) runs this
        // at both sizes. The generous batch_timeout makes fusion
        // deterministic: the whole burst is enqueued within the first
        // batch's fill window, so 24 requests cannot come out as 24
        // singleton batches unless the batcher is broken.
        let model = testutil::tiny_model();
        let reference = Engine::new(model.clone());
        let cfg = ServiceConfig {
            family: Family::Perforated,
            m: 2,
            use_cv: true,
            // env-driven (the CI sweep pins 1 and 4) but capped well below
            // the 24-request burst: with ~one worker per request, each
            // push can legally wake a fresh worker into its own singleton
            // batch and the fusion assertion below would be meaningless.
            workers: default_service_workers().min(4),
            batch_size: 8,
            batch_timeout: Duration::from_millis(50),
            ..Default::default()
        };
        let svc = InferenceService::start(Engine::new(model), cfg).unwrap();
        let opts = ForwardOpts::approx(Family::Perforated, 2, true);
        let imgs: Vec<Tensor> =
            (0..24).map(|i| testutil::tiny_image(i as u64)).collect();
        let pendings: Vec<Pending> =
            imgs.iter().map(|im| svc.submit(im.clone()).unwrap()).collect();
        for (img, p) in imgs.iter().zip(pendings) {
            let reply = p.wait().unwrap();
            assert_eq!(reply.logits, reference.forward(img, &opts).unwrap());
        }
        let snap = svc.shutdown();
        assert_eq!(snap.completed, 24);
        assert!(
            snap.batches < snap.completed && snap.mean_batch_size > 1.0,
            "burst must fuse into multi-request batches: {} batches, mean {}",
            snap.batches,
            snap.mean_batch_size
        );
    }

    #[test]
    fn nan_logits_are_errors_not_panics() {
        // A model whose logits dequantize to NaN must not kill any worker:
        // requests answer with Err, the pool keeps serving, shutdown is
        // clean. (The seed's argmax panicked the worker on the first NaN
        // and the next submit panicked the caller.)
        let cfg = ServiceConfig {
            family: Family::Perforated,
            m: 2,
            use_cv: true,
            // env-driven default: the CI sweep runs this at 1 and 4 workers
            workers: default_service_workers(),
            batch_size: 4,
            ..Default::default()
        };
        let svc =
            InferenceService::start(Engine::new(testutil::nan_logit_model()), cfg).unwrap();
        for _ in 0..2 {
            let pend: Vec<Pending> = (0..4)
                .map(|i| svc.submit(testutil::tiny_image(i)).unwrap())
                .collect();
            for p in pend {
                let err = p.wait().unwrap_err();
                assert!(format!("{err:#}").contains("NaN"), "{err:#}");
            }
        }
        // still alive and accepting after 8 NaN results
        assert!(svc.submit(testutil::tiny_image(99)).is_ok());
        let snap = svc.shutdown();
        assert!(snap.completed >= 8);
    }

    #[test]
    fn submit_after_close_errors_instead_of_panicking() {
        let svc = InferenceService::start(
            Engine::new(testutil::tiny_model()),
            ServiceConfig { workers: 1, ..Default::default() },
        ).unwrap();
        let p = svc.submit(testutil::tiny_image(1)).unwrap();
        assert!(p.wait().is_ok());
        svc.close();
        assert!(svc.submit(testutil::tiny_image(2)).is_err());
        assert!(svc.infer(testutil::tiny_image(3)).is_err());
        let snap = svc.shutdown();
        assert_eq!(snap.completed, 1);
    }

    #[test]
    fn wrong_shape_request_fails_alone() {
        let model = testutil::tiny_model();
        let reference = Engine::new(model.clone());
        let svc = InferenceService::start(
            Engine::new(model),
            ServiceConfig { workers: 1, batch_size: 4, ..Default::default() },
        ).unwrap();
        let good = testutil::tiny_image(7);
        let bad = Tensor::new(2, 2, 1);
        let p_good = svc.submit(good.clone()).unwrap();
        let p_bad = svc.submit(bad).unwrap();
        let want = reference.forward(&good, &ForwardOpts::exact()).unwrap();
        assert_eq!(p_good.wait().unwrap().logits, want);
        let err = p_bad.wait().unwrap_err();
        assert!(format!("{err:#}").contains("shape"), "{err:#}");
        svc.shutdown();
    }

    #[test]
    fn single_request_session_reports_throughput() {
        let svc = InferenceService::start(
            Engine::new(testutil::tiny_model()),
            ServiceConfig { workers: 2, ..Default::default() },
        ).unwrap();
        svc.infer(testutil::tiny_image(0)).unwrap();
        let snap = svc.shutdown();
        assert_eq!(snap.completed, 1);
        assert!(
            snap.throughput_rps > 0.0,
            "one-request session must report a rate (was the start anchor lost?)"
        );
    }

    #[test]
    fn policy_service_serves_mixed_batches_bit_identically() {
        // The tentpole acceptance path: a mixed per-layer policy flows
        // through the worker pool (batched forwards, shared plan cache) and
        // every reply is bit-equal to the per-image policy forward.
        let model = testutil::tiny_model(); // 2 MAC layers
        let reference = Engine::new(model.clone());
        let policy = std::sync::Arc::new(
            LayerPolicy::from_ms(Family::Perforated, &[2, 0], true).unwrap(),
        );
        let cfg = ServiceConfig {
            policy: Some(policy.clone()),
            workers: 2,
            batch_size: 4,
            batch_timeout: Duration::from_millis(20),
            ..Default::default()
        };
        let svc = InferenceService::start(Engine::new(model), cfg).unwrap();
        let opts = ForwardOpts::with_policy(policy);
        let imgs: Vec<Tensor> =
            (0..16).map(|i| testutil::tiny_image(1000 + i)).collect();
        let pendings: Vec<Pending> =
            imgs.iter().map(|im| svc.submit(im.clone()).unwrap()).collect();
        for (img, p) in imgs.iter().zip(pendings) {
            let reply = p.wait().unwrap();
            assert_eq!(reply.logits, reference.forward(img, &opts).unwrap());
        }
        // Wrong-shape requests still fail alone under a policy config.
        let err = svc.infer(Tensor::new(2, 2, 1)).unwrap_err();
        assert!(format!("{err:#}").contains("shape"), "{err:#}");
        let snap = svc.shutdown();
        assert_eq!(snap.completed, 16);
        // Mixed power estimate: strictly between the aggressive uniform
        // point and exact.
        let uniform = PowerModel::new(Family::Perforated, 2, 64).power_norm;
        assert!(snap.energy_vs_exact > uniform && snap.energy_vs_exact < 1.0);
    }

    #[test]
    fn paired_policy_service_serves_bit_identically() {
        // A positive/negative paired policy flows through the worker pool
        // (batched forwards, shared paired-plan cache) and every reply is
        // bit-equal to the per-image paired forward; the estimated power of
        // a mirrored pairing equals the uniform point's.
        let model = testutil::tiny_model(); // 2 MAC layers
        let reference = Engine::new(model.clone());
        let policy = std::sync::Arc::new(
            LayerPolicy::paired_uniform(Family::Perforated, 2, true, 2).unwrap(),
        );
        let cfg = ServiceConfig {
            policy: Some(policy.clone()),
            workers: 2,
            batch_size: 4,
            batch_timeout: Duration::from_millis(20),
            ..Default::default()
        };
        let svc = InferenceService::start(Engine::new(model), cfg).unwrap();
        let opts = ForwardOpts::with_policy(policy);
        let imgs: Vec<Tensor> =
            (0..12).map(|i| testutil::tiny_image(2000 + i)).collect();
        let pendings: Vec<Pending> =
            imgs.iter().map(|im| svc.submit(im.clone()).unwrap()).collect();
        for (img, p) in imgs.iter().zip(pendings) {
            let reply = p.wait().unwrap();
            assert_eq!(reply.logits, reference.forward(img, &opts).unwrap());
        }
        let snap = svc.shutdown();
        assert_eq!(snap.completed, 12);
        let uniform = PowerModel::new(Family::Perforated, 2, 64).power_norm;
        assert!(
            (snap.energy_vs_exact - uniform).abs() < 1e-9,
            "mirrored pairing is power-neutral vs the uniform point: {} vs {uniform}",
            snap.energy_vs_exact
        );
    }

    #[test]
    fn start_rejects_mismatched_policy_before_spawning() {
        // 3 policy layers vs tiny_model's 2 MAC layers: start must fail
        // (nothing spawns, nothing to poison) — and a subsequent valid
        // service on the same config shape works fine.
        let bad = std::sync::Arc::new(
            LayerPolicy::uniform(Family::Perforated, 2, true, 3).unwrap(),
        );
        let err = InferenceService::start(
            Engine::new(testutil::tiny_model()),
            ServiceConfig { policy: Some(bad), workers: 2, ..Default::default() },
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("MAC layers"), "{err:#}");
        let good = std::sync::Arc::new(
            LayerPolicy::uniform(Family::Perforated, 2, true, 2).unwrap(),
        );
        let svc = InferenceService::start(
            Engine::new(testutil::tiny_model()),
            ServiceConfig { policy: Some(good), workers: 2, ..Default::default() },
        )
        .unwrap();
        assert!(svc.infer(testutil::tiny_image(5)).is_ok());
        svc.shutdown();
    }

    #[test]
    fn oversized_k_is_a_start_and_install_error_not_a_worker_crash() {
        // K-headroom regression (see `nn::gemm::max_k_for_point`): a dense
        // layer with K above MAX_K_POS used to panic a serving worker
        // mid-batch when served at positive polarity — caught by
        // catch_unwind, costing the whole batch a WorkerCrashed. It must be
        // a typed error at start/install time instead.
        use crate::nn::gemm::MAX_K_POS;
        use crate::nn::policy::LayerPoint;
        use crate::approx::Polarity;
        let k = MAX_K_POS + 1_000;
        let pos = std::sync::Arc::new(
            LayerPolicy::new(vec![LayerPoint::new_pol(
                Family::Perforated,
                2,
                Polarity::Pos,
                true,
            )])
            .unwrap(),
        );
        // Starting straight onto the bad policy fails before any worker
        // spawns.
        let err = InferenceService::start(
            Engine::new(testutil::big_k_model(k)),
            ServiceConfig { policy: Some(pos.clone()), workers: 1, ..Default::default() },
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("i32-headroom"), "{err:#}");
        // Exact serving of the same model is fine; hot-swapping to the bad
        // policy is rejected and the running generation keeps serving.
        let svc = InferenceService::start(
            Engine::new(testutil::big_k_model(k)),
            ServiceConfig { workers: 1, ..Default::default() },
        )
        .unwrap();
        let err = svc.install_policy(pos).unwrap_err();
        assert!(format!("{err:#}").contains("i32-headroom"), "{err:#}");
        let reply = svc.submit(testutil::big_k_image(k)).unwrap().wait().unwrap();
        assert_eq!(reply.logits.len(), 2);
        let snap = svc.shutdown();
        assert_eq!(snap.worker_restarts, 0, "no worker may have panicked");
        assert_eq!(snap.crashed_replies, 0);
    }

    #[test]
    fn resolve_policy_sources_and_errors() {
        let dir = std::env::temp_dir();
        let ok_path = dir.join(format!("cvapprox_policy_ok_{}.txt", std::process::id()));
        let bad_path = dir.join(format!("cvapprox_policy_bad_{}.txt", std::process::id()));
        std::fs::write(&ok_path, "perforated 2 cv\nexact\n").unwrap();
        std::fs::write(&bad_path, "bogusfamily 2 cv\n").unwrap();

        // No sources -> no policy.
        assert!(resolve_policy(None, None).unwrap().is_none());
        assert!(resolve_policy(None, Some("  ")).unwrap().is_none());
        // Env path loads the file.
        let loaded = resolve_policy(None, Some(ok_path.to_str().unwrap()))
            .unwrap()
            .unwrap();
        assert_eq!(loaded.len(), 2);
        assert_eq!(loaded.approx_layers(), 1);
        // Unknown family / missing file surface as Err, tagged with the knob.
        let err = resolve_policy(None, Some(bad_path.to_str().unwrap())).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("CVAPPROX_SERVICE_POLICY"), "{msg}");
        assert!(msg.contains("unknown family"), "{msg}");
        assert!(resolve_policy(None, Some("/nonexistent/policy.json")).is_err());
        // Explicit config policy wins over the env path.
        let explicit = std::sync::Arc::new(
            LayerPolicy::uniform(Family::Truncated, 6, true, 2).unwrap(),
        );
        let got = resolve_policy(
            Some(&explicit),
            Some(bad_path.to_str().unwrap()),
        )
        .unwrap()
        .unwrap();
        assert_eq!(
            got.as_uniform().unwrap(),
            crate::nn::LayerPoint::new(Family::Truncated, 6, true)
        );
        let _ = std::fs::remove_file(&ok_path);
        let _ = std::fs::remove_file(&bad_path);
    }

    #[test]
    fn hot_swap_replies_bit_identical_under_concurrent_random_swaps() {
        // The hot-swap consistency property: while a swapper thread installs
        // random ladder rungs into the live pool, every reply must be
        // bit-identical to a single-policy forward under the rung its epoch
        // names — i.e. no batch ever mixes two policies, and the epoch
        // stamp is never wrong.
        let model = testutil::tiny_model(); // 2 MAC layers
        let rungs: Vec<SharedPolicy> = vec![
            Arc::new(LayerPolicy::uniform(Family::Exact, 0, false, 2).unwrap()),
            Arc::new(LayerPolicy::from_ms(Family::Perforated, &[2, 0], true).unwrap()),
            Arc::new(LayerPolicy::paired_uniform(Family::Perforated, 2, true, 2).unwrap()),
            Arc::new(LayerPolicy::uniform(Family::Truncated, 6, true, 2).unwrap()),
        ];
        let svc = InferenceService::start(
            Engine::new(model.clone()),
            ServiceConfig {
                workers: 3,
                batch_size: 4,
                batch_timeout: Duration::from_micros(500),
                ..Default::default()
            },
        )
        .unwrap();
        // epoch -> rung index; epoch 0 is the start config (uniform exact),
        // which rungs[0] reproduces bit-for-bit.
        let epoch_map: Mutex<std::collections::HashMap<u64, usize>> =
            Mutex::new(std::collections::HashMap::from([(0u64, 0usize)]));
        let reference = Engine::new(model);
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let clients = 4usize;
        let per_client = 40usize;
        let mut seen_epochs = std::collections::HashSet::new();
        std::thread::scope(|s| {
            // Swapper: random-ish walk over the rungs, installing under the
            // epoch_map lock so clients can always resolve a reply's epoch.
            {
                let svc = &svc;
                let epoch_map = &epoch_map;
                let rungs = &rungs;
                let stop = stop.clone();
                s.spawn(move || {
                    let mut i = 1usize;
                    while !stop.load(Ordering::SeqCst) {
                        let r = (i * 7 + 3) % rungs.len();
                        let mut map = lock_clean(epoch_map);
                        let epoch = svc.install_policy(rungs[r].clone()).unwrap();
                        map.insert(epoch, r);
                        drop(map);
                        i += 1;
                        std::thread::sleep(Duration::from_micros(300));
                    }
                });
            }
            let mut handles = Vec::new();
            for t in 0..clients {
                let svc = &svc;
                let reference = &reference;
                let epoch_map = &epoch_map;
                let rungs = &rungs;
                handles.push(s.spawn(move || {
                    let mut epochs = Vec::new();
                    for i in 0..per_client {
                        let img = testutil::tiny_image((t * 1000 + i) as u64);
                        let reply = svc.infer(img.clone()).unwrap();
                        let rung = {
                            // The swapper publishes the mapping under the
                            // same lock it installs under, so the reply's
                            // epoch is always resolvable.
                            let map = lock_clean(epoch_map);
                            *map.get(&reply.epoch).unwrap_or_else(|| {
                                panic!("reply epoch {} not in map", reply.epoch)
                            })
                        };
                        let opts = ForwardOpts::with_policy(rungs[rung].clone());
                        let want = reference.forward(&img, &opts).unwrap();
                        assert_eq!(
                            reply.logits, want,
                            "client {t} img {i}: reply (epoch {}, rung {rung}) \
                             not bit-identical to its rung's static forward",
                            reply.epoch
                        );
                        epochs.push(reply.epoch);
                    }
                    epochs
                }));
            }
            let mut all = Vec::new();
            for h in handles {
                all.extend(h.join().unwrap());
            }
            stop.store(true, Ordering::SeqCst);
            seen_epochs.extend(all);
        });
        let snap = svc.shutdown();
        assert_eq!(snap.completed, (clients * per_client) as u64);
        assert!(
            seen_epochs.len() >= 2,
            "swaps never landed mid-traffic (epochs {seen_epochs:?})"
        );
    }

    #[test]
    fn shutdown_drains_queue_while_policies_step() {
        // Satellite: shutdown must drain every queued request to an Ok
        // reply even while a stepping thread keeps hot-swapping policies.
        let model = testutil::tiny_model();
        let rungs: Vec<SharedPolicy> = vec![
            Arc::new(LayerPolicy::uniform(Family::Exact, 0, false, 2).unwrap()),
            Arc::new(LayerPolicy::from_ms(Family::Perforated, &[2, 0], true).unwrap()),
            Arc::new(LayerPolicy::uniform(Family::Perforated, 3, true, 2).unwrap()),
        ];
        let svc = InferenceService::start(
            Engine::new(model),
            ServiceConfig {
                workers: 2,
                batch_size: 4,
                batch_timeout: Duration::from_millis(1),
                ..Default::default()
            },
        )
        .unwrap();
        let installer = svc.installer();
        let pendings: Vec<Pending> = (0..64)
            .map(|i| svc.submit(testutil::tiny_image(i)).unwrap())
            .collect();
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let stepper = {
            let stop = stop.clone();
            let rungs = rungs.clone();
            std::thread::spawn(move || {
                let mut i = 0usize;
                while !stop.load(Ordering::SeqCst) {
                    installer.install(rungs[i % rungs.len()].clone()).unwrap();
                    i += 1;
                    std::thread::sleep(Duration::from_micros(200));
                }
                i
            })
        };
        let snap = svc.shutdown();
        stop.store(true, Ordering::SeqCst);
        let steps = stepper.join().unwrap();
        assert_eq!(snap.completed, 64, "shutdown must drain the whole queue");
        for p in pendings {
            p.wait().unwrap();
        }
        assert!(steps >= 1, "the stepper never stepped");
    }

    #[test]
    fn install_policy_swaps_between_requests_and_rejects_bad_policies() {
        let model = testutil::tiny_model();
        let reference = Engine::new(model.clone());
        let svc = InferenceService::start(
            Engine::new(model),
            ServiceConfig { workers: 1, ..Default::default() },
        )
        .unwrap();
        assert_eq!(svc.current_epoch(), 0);
        let img = testutil::tiny_image(11);
        let r0 = svc.infer(img.clone()).unwrap();
        assert_eq!(r0.epoch, 0);
        assert_eq!(r0.logits, reference.forward(&img, &ForwardOpts::exact()).unwrap());
        // Install an approximate policy; subsequent replies serve it.
        let p = Arc::new(LayerPolicy::uniform(Family::Perforated, 2, true, 2).unwrap());
        let epoch = svc.install_policy(p.clone()).unwrap();
        assert_eq!(epoch, 1);
        assert_eq!(svc.current_epoch(), 1);
        let r1 = svc.infer(img.clone()).unwrap();
        assert_eq!(r1.epoch, 1);
        assert_eq!(r1.logits, reference.forward(&img, &ForwardOpts::with_policy(p)).unwrap());
        // A mismatched policy is rejected and leaves the pool serving.
        let bad = Arc::new(LayerPolicy::uniform(Family::Perforated, 2, true, 5).unwrap());
        let err = svc.install_policy(bad).unwrap_err();
        assert!(format!("{err:#}").contains("MAC layers"), "{err:#}");
        assert_eq!(svc.current_epoch(), 1, "failed install must not bump the epoch");
        assert!(svc.infer(testutil::tiny_image(12)).is_ok());
        // Energy accounting follows the serving rung: the approximate rung
        // must have pulled the blended energy below exact.
        let snap = svc.shutdown();
        assert!(snap.energy_vs_exact < 1.0, "{}", snap.energy_vs_exact);
    }

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[1.0, 5.0, 3.0]), 1);
        assert_eq!(argmax(&[]), 0);
    }

    #[test]
    fn argmax_is_nan_safe() {
        assert_eq!(argmax(&[f64::NAN, 1.0, f64::NAN, 0.5]), 1);
        assert_eq!(argmax(&[f64::NAN, f64::NAN]), 0);
        assert_eq!(argmax(&[-1.0, f64::NAN]), 0);
        // ties keep last-max semantics, matching the old Iterator::max_by
        assert_eq!(argmax(&[2.0, 2.0, 1.0]), 1);
        assert_eq!(argmax(&[f64::NEG_INFINITY, f64::NEG_INFINITY]), 1);
    }

    // ---- fault tolerance & self-healing (tentpole) -------------------------

    #[test]
    fn reply_error_typing_is_stable() {
        assert!(ReplyError::Overloaded.retryable());
        assert!(ReplyError::WorkerCrashed.retryable());
        assert!(!ReplyError::Closed.retryable());
        assert!(!ReplyError::Deadline.retryable());
        assert!(!ReplyError::Integrity.retryable());
        assert!(!ReplyError::BadInput("x".into()).retryable());
        assert!(ReplyError::Overloaded.to_string().contains("overloaded"));
        assert_eq!(ReplyError::BadInput("bad shape".into()).to_string(), "bad shape");
    }

    #[test]
    fn close_twice_then_shutdown_is_clean() {
        let svc = InferenceService::start(
            Engine::new(testutil::tiny_model()),
            ServiceConfig { workers: 2, ..Default::default() },
        )
        .unwrap();
        svc.close();
        svc.close(); // idempotent: the second close is a no-op, not a panic
        let err = svc.try_submit(testutil::tiny_image(0), None).unwrap_err();
        assert_eq!(err, ReplyError::Closed);
        let snap = svc.shutdown();
        assert_eq!(snap.completed, 0);
    }

    #[test]
    fn bounded_queue_rejects_overload_with_typed_error() {
        // One slow worker (every batch spikes 25 ms), queue capped at 2: a
        // 12-burst must see typed Overloaded rejections, every accepted
        // request must still resolve, and the rejection counter must match.
        let cfg = ServiceConfig {
            workers: 1,
            batch_size: 1,
            queue_cap: 2,
            faults: Some(FaultConfig {
                spike_per_mille: 1000,
                spike: Duration::from_millis(25),
                ..FaultConfig::quiet(5)
            }),
            ..Default::default()
        };
        let svc = InferenceService::start(Engine::new(testutil::tiny_model()), cfg).unwrap();
        let mut accepted = Vec::new();
        let mut rejected = 0u64;
        for i in 0..12 {
            match svc.try_submit(testutil::tiny_image(i), None) {
                Ok(p) => accepted.push(p),
                Err(e) => {
                    assert_eq!(e, ReplyError::Overloaded);
                    rejected += 1;
                }
            }
        }
        assert!(rejected > 0, "queue_cap=2 must reject part of an instant 12-burst");
        for p in accepted {
            p.wait_reply().unwrap();
        }
        let snap = svc.shutdown();
        assert_eq!(snap.rejected_overload, rejected);
        assert!(snap.completed >= 1);
    }

    #[test]
    fn deadline_expires_at_dequeue_with_typed_error() {
        // Worker busy for 30 ms per batch; request B carries a 5 ms budget
        // and can only be dequeued after A's batch — it must answer
        // Err(Deadline) without ever spending a batch slot.
        let cfg = ServiceConfig {
            workers: 1,
            batch_size: 1,
            faults: Some(FaultConfig {
                spike_per_mille: 1000,
                spike: Duration::from_millis(30),
                ..FaultConfig::quiet(6)
            }),
            ..Default::default()
        };
        let svc = InferenceService::start(Engine::new(testutil::tiny_model()), cfg).unwrap();
        let pa = svc.submit(testutil::tiny_image(0)).unwrap();
        std::thread::sleep(Duration::from_millis(5));
        let pb = svc
            .submit_with_deadline(testutil::tiny_image(1), Duration::from_millis(5))
            .unwrap();
        assert!(pa.wait().is_ok());
        assert_eq!(pb.wait_reply().unwrap_err(), ReplyError::Deadline);
        let snap = svc.shutdown();
        assert_eq!(snap.expired_deadline, 1);
        assert_eq!(snap.completed, 1);
    }

    #[test]
    fn injected_panics_get_typed_replies_and_pool_respawns() {
        // Under a 300‰ panic schedule the pool keeps serving: crashed
        // batches answer WorkerCrashed (retryable), the supervisor respawns
        // replacements, and retried requests come back bit-identical.
        let model = testutil::tiny_model();
        let reference = Engine::new(model.clone());
        let cfg = ServiceConfig {
            family: Family::Perforated,
            m: 2,
            use_cv: true,
            workers: 2,
            batch_size: 2,
            faults: Some(FaultConfig { panic_per_mille: 300, ..FaultConfig::quiet(77) }),
            ..Default::default()
        };
        let svc = InferenceService::start(Engine::new(model), cfg).unwrap();
        let opts = ForwardOpts::approx(Family::Perforated, 2, true);
        for i in 0..40u64 {
            let img = testutil::tiny_image(i);
            let reply = svc
                .infer_with_retry(&img, 20, Duration::from_micros(200))
                .expect("retry must eventually land on a surviving worker");
            assert_eq!(reply.logits, reference.forward(&img, &opts).unwrap(), "img {i}");
        }
        let snap = svc.shutdown();
        assert_eq!(snap.completed, 40);
        assert!(snap.worker_restarts >= 1, "no crash was ever supervised");
        assert!(snap.crashed_replies >= 1, "no in-flight batch was ever stranded");
    }

    #[test]
    fn shutdown_drains_queue_while_workers_crash_loop() {
        // Satellite: shutdown with a crash-looping pool (500‰ panics) must
        // still resolve every one of 80 queued requests — Ok or typed — and
        // never hang. The supervisor keeps respawning while queued work
        // remains, even though the service is already stopping.
        let cfg = ServiceConfig {
            workers: 2,
            batch_size: 2,
            faults: Some(FaultConfig { panic_per_mille: 500, ..FaultConfig::quiet(4242) }),
            ..Default::default()
        };
        let svc = InferenceService::start(Engine::new(testutil::tiny_model()), cfg).unwrap();
        let pendings: Vec<Pending> = (0..80)
            .map(|i| svc.submit(testutil::tiny_image(i)).unwrap())
            .collect();
        let snap = svc.shutdown();
        let (mut ok, mut typed) = (0u64, 0u64);
        for p in pendings {
            match p.wait_reply() {
                Ok(_) => ok += 1,
                Err(e) => {
                    assert!(
                        matches!(
                            e,
                            ReplyError::WorkerCrashed
                                | ReplyError::Closed
                                | ReplyError::Integrity
                        ),
                        "unexpected terminal error: {e}"
                    );
                    typed += 1;
                }
            }
        }
        assert_eq!(ok + typed, 80, "every request resolves exactly once");
        assert_eq!(snap.completed, ok);
        assert!(snap.worker_restarts >= 1, "the supervisor never respawned");
    }

    #[test]
    fn lut_corruption_heals_and_replies_stay_bit_identical() {
        // Tentpole acceptance: poison a prepared LUT stripe behind a live
        // pool's back; the next batch detects it (chaos mode verifies per
        // batch), heals from the structural bitmodel, replays, and answers
        // bit-identically to the fault-free reference.
        let model = testutil::tiny_model();
        let reference = Engine::new(model.clone());
        let mut engine = Engine::new(model);
        engine.prepare_lut(Family::Perforated, 2);
        let cfg = ServiceConfig {
            family: Family::Perforated,
            m: 2,
            use_cv: true,
            workers: 1,
            batch_size: 4,
            faults: Some(FaultConfig::quiet(9)),
            ..Default::default()
        };
        let svc = InferenceService::start(engine, cfg).unwrap();
        let opts = ForwardOpts::approx(Family::Perforated, 2, true);
        let img = testutil::tiny_image(31);
        let want = reference.forward(&img, &opts).unwrap();
        assert_eq!(svc.infer(img.clone()).unwrap().logits, want);
        let hit = svc.engine().corrupt_lut(0, 0, 256, 22);
        assert!(hit.is_some(), "a prepared LUT must exist to corrupt");
        assert!(!svc.engine().verify_integrity().is_clean());
        assert_eq!(svc.infer(img.clone()).unwrap().logits, want);
        assert!(svc.engine().verify_integrity().is_clean(), "healing must stick");
        let snap = svc.shutdown();
        assert!(snap.heal_events >= 1, "corruption was never healed");
        assert!(snap.replayed_batches >= 1, "the poisoned batch was never replayed");
    }

    #[test]
    fn plan_corruption_heals_end_to_end() {
        // Same tentpole path through the other cache: a flipped bit in a
        // packed weight panel is caught by the plan checksum, the plan is
        // invalidated (rebuilt from pristine weights on the replay), and
        // the reply stays bit-identical.
        let model = testutil::tiny_model();
        let reference = Engine::new(model.clone());
        let cfg = ServiceConfig {
            family: Family::Perforated,
            m: 2,
            use_cv: true,
            workers: 1,
            batch_size: 4,
            faults: Some(FaultConfig::quiet(10)),
            ..Default::default()
        };
        let svc = InferenceService::start(Engine::new(model), cfg).unwrap();
        let opts = ForwardOpts::approx(Family::Perforated, 2, true);
        let img = testutil::tiny_image(32);
        let want = reference.forward(&img, &opts).unwrap();
        assert_eq!(svc.infer(img.clone()).unwrap().logits, want);
        let hit = svc.engine().corrupt_plan(0, 3, 2);
        assert!(hit.is_some(), "start() warms plans; the cache cannot be empty");
        assert!(!svc.engine().verify_integrity().is_clean());
        assert_eq!(svc.infer(img.clone()).unwrap().logits, want);
        assert!(svc.engine().verify_integrity().is_clean(), "healing must stick");
        let snap = svc.shutdown();
        assert!(snap.heal_events >= 1);
        assert!(snap.replayed_batches >= 1);
    }

    /// Body of the chaos property, parameterized over the queue shape so
    /// the sharded work-stealing path is held to exactly the ISSUE 6 bar
    /// the single queue was: under a mixed fault schedule every submitted
    /// request resolves to exactly one reply — Ok and bit-identical to the
    /// fault-free reference, or a typed error. No hang, no silent
    /// corruption.
    fn chaos_roundtrip(shards: usize, workers: usize) {
        let model = testutil::tiny_model();
        let reference = Engine::new(model.clone());
        let mut engine = Engine::new(model);
        engine.prepare_lut(Family::Perforated, 2);
        let cfg = ServiceConfig {
            family: Family::Perforated,
            m: 2,
            use_cv: true,
            workers,
            shards,
            batch_size: 2,
            faults: Some(FaultConfig {
                seed: 20260808,
                lut_flip_per_mille: 60,
                plan_flip_per_mille: 40,
                panic_per_mille: 60,
                spike_per_mille: 40,
                spike: Duration::from_millis(1),
                drop_per_mille: 30,
            }),
            ..Default::default()
        };
        let svc = InferenceService::start(engine, cfg).unwrap();
        let opts = ForwardOpts::approx(Family::Perforated, 2, true);
        let imgs: Vec<Tensor> = (0..120).map(|i| testutil::tiny_image(i as u64)).collect();
        let pendings: Vec<Pending> =
            imgs.iter().map(|im| svc.submit(im.clone()).unwrap()).collect();
        let (mut ok, mut typed) = (0u64, 0u64);
        for (img, p) in imgs.iter().zip(pendings) {
            match p.wait_reply() {
                Ok(reply) => {
                    assert_eq!(
                        reply.logits,
                        reference.forward(img, &opts).unwrap(),
                        "silent corruption: an Ok reply diverged from the reference"
                    );
                    ok += 1;
                }
                Err(e) => {
                    assert!(
                        matches!(e, ReplyError::WorkerCrashed | ReplyError::Integrity),
                        "unexpected error under chaos: {e}"
                    );
                    typed += 1;
                }
            }
        }
        assert_eq!(ok + typed, 120, "exactly one reply per request");
        assert!(ok > 0, "chaos at these rates must still serve most requests");
        let snap = svc.shutdown();
        assert!(snap.injected_faults > 0, "the schedule never fired across ~60+ batches");
        assert!(snap.completed >= ok);
    }

    #[test]
    fn chaos_every_request_gets_exactly_one_reply_ok_or_typed() {
        // shards=1 reproduces the legacy single-queue shape.
        chaos_roundtrip(1, 2);
    }

    #[test]
    fn chaos_property_holds_on_sharded_queue() {
        // Acceptance: the same property at shards=4 — fault schedules
        // address the sharded pool (pool-wide batch_seq) exactly like the
        // single queue.
        chaos_roundtrip(4, 4);
    }

    #[test]
    fn lone_tight_deadline_request_is_served_not_expired() {
        // PR 9 headline regression: a lone request with a 5 ms budget
        // under a 50 ms batch window. The deadline-blind batcher held it
        // the full window and then rejected it at the dequeue screen; the
        // deadline-aware fill-wait must cap the wait at the deadline and
        // serve it in time.
        let cfg = ServiceConfig {
            workers: 1,
            batch_size: 8,
            batch_timeout: Duration::from_millis(50),
            ..Default::default()
        };
        let svc = InferenceService::start(Engine::new(testutil::tiny_model()), cfg).unwrap();
        let t0 = Instant::now();
        let p = svc
            .submit_with_deadline(testutil::tiny_image(0), Duration::from_millis(5))
            .unwrap();
        let reply = p.wait_reply();
        let elapsed = t0.elapsed();
        assert!(
            reply.is_ok(),
            "tight-deadline request under a long batch window must be served, got {reply:?}"
        );
        assert!(
            elapsed < Duration::from_millis(45),
            "reply took {elapsed:?}: the fill-wait ran the full 50 ms window \
             instead of capping at the 5 ms deadline"
        );
        let snap = svc.shutdown();
        assert_eq!(snap.expired_deadline, 0, "nothing may expire");
        assert_eq!(snap.completed, 1);
    }

    #[test]
    fn idle_pool_skips_the_fill_wait() {
        // Companion satellite: when nothing else is queued and another
        // worker sits parked, filling the batch gains nothing — the
        // batcher must run the singleton immediately instead of sleeping
        // out the window. Four sequential no-deadline requests under a
        // 150 ms window would cost >= 600 ms deadline-blind; with the
        // idle-skip they return almost instantly.
        let cfg = ServiceConfig {
            workers: 2,
            batch_size: 8,
            batch_timeout: Duration::from_millis(150),
            ..Default::default()
        };
        let svc = InferenceService::start(Engine::new(testutil::tiny_model()), cfg).unwrap();
        std::thread::sleep(Duration::from_millis(20)); // let both workers park
        let t0 = Instant::now();
        for i in 0..4u64 {
            svc.infer(testutil::tiny_image(i)).unwrap();
        }
        let elapsed = t0.elapsed();
        assert!(
            elapsed < Duration::from_millis(450),
            "4 sequential singleton requests took {elapsed:?}: the idle-skip \
             never engaged (deadline-blind cost would be >= 600 ms)"
        );
        let snap = svc.shutdown();
        assert_eq!(snap.completed, 4);
    }

    #[test]
    fn sharded_pool_serves_concurrent_clients_bit_identically() {
        // The work-stealing tentpole under real concurrency: explicit
        // shards=4 / workers=4, six hammering clients, every reply
        // bit-equal to a single-threaded forward on an identical engine.
        let model = testutil::tiny_model();
        let reference = Engine::new(model.clone());
        let cfg = ServiceConfig {
            family: Family::Truncated,
            m: 6,
            use_cv: true,
            workers: 4,
            shards: 4,
            batch_size: 4,
            ..Default::default()
        };
        let svc = InferenceService::start(Engine::new(model), cfg).unwrap();
        let opts = ForwardOpts::approx(Family::Truncated, 6, true);
        let clients = 6usize;
        let per_client = 8usize;
        std::thread::scope(|s| {
            for t in 0..clients {
                let svc = &svc;
                let reference = &reference;
                let opts = &opts;
                s.spawn(move || {
                    for i in 0..per_client {
                        let img = testutil::tiny_image((t * 1000 + i) as u64);
                        let reply = svc.infer(img.clone()).unwrap();
                        let want = reference.forward(&img, opts).unwrap();
                        assert_eq!(reply.logits, want, "client {t} img {i}");
                        assert_eq!(reply.tenant, 0);
                    }
                });
            }
        });
        let snap = svc.shutdown();
        assert_eq!(snap.completed, (clients * per_client) as u64);
        assert_eq!(snap.worker_requests.iter().sum::<u64>(), snap.completed);
    }

    #[test]
    fn tenant_classes_isolate_policies_and_metrics() {
        // Two tenants over one pool: installing an approximate policy into
        // class 1 must leave class 0 serving exact, both bit-identical to
        // their own references, with partitioned per-class metrics rows.
        let model = testutil::tiny_model();
        let reference = Engine::new(model.clone());
        let cfg = ServiceConfig {
            workers: 2,
            batch_size: 4,
            tenants: vec![TenantClass::new("light"), TenantClass::new("heavy")],
            ..Default::default()
        };
        let svc = InferenceService::start(Engine::new(model), cfg).unwrap();
        let approx: SharedPolicy =
            Arc::new(crate::nn::LayerPolicy::uniform(Family::Perforated, 2, true, 2).unwrap());
        let epoch1 = svc.install_policy_for(1, approx.clone()).unwrap();
        assert_eq!(epoch1, 1);
        assert_eq!(svc.current_epoch_for(0), 0, "class 0's plane must not move");
        assert_eq!(svc.current_epoch_for(1), 1);
        let exact_opts = ForwardOpts::default();
        let approx_opts = ForwardOpts::with_policy(approx);
        for i in 0..12u64 {
            let img = testutil::tiny_image(i);
            let r0 = svc.submit_for(0, img.clone()).unwrap().wait().unwrap();
            assert_eq!(r0.logits, reference.forward(&img, &exact_opts).unwrap(), "light {i}");
            assert_eq!((r0.tenant, r0.epoch), (0, 0));
            let r1 = svc.submit_for(1, img.clone()).unwrap().wait().unwrap();
            assert_eq!(r1.logits, reference.forward(&img, &approx_opts).unwrap(), "heavy {i}");
            assert_eq!((r1.tenant, r1.epoch), (1, 1));
        }
        // Unknown class: typed rejection, never a panic.
        assert!(matches!(
            svc.try_submit_for(7, testutil::tiny_image(0), None),
            Err(ReplyError::BadInput(_))
        ));
        let snap = svc.shutdown();
        assert_eq!(snap.completed, 24);
        assert_eq!(snap.classes.len(), 2);
        assert_eq!(snap.classes[0].name, "light");
        assert_eq!(snap.classes[1].name, "heavy");
        assert_eq!(snap.classes[0].completed, 12);
        assert_eq!(snap.classes[1].completed, 12);
    }

    #[test]
    fn tenant_default_deadline_and_admission_bound_apply_per_class() {
        // Class 1 carries a 5 ms default deadline; with every batch
        // spiking 30 ms its submit (no explicit deadline) must expire at
        // dequeue while class 0's request is served — and the expiry lands
        // in class 1's metrics row only.
        let mut tight = TenantClass::new("tight");
        tight.default_deadline = Some(Duration::from_millis(5));
        let cfg = ServiceConfig {
            workers: 1,
            batch_size: 1,
            tenants: vec![TenantClass::new("lax"), tight],
            faults: Some(FaultConfig {
                spike_per_mille: 1000,
                spike: Duration::from_millis(30),
                ..FaultConfig::quiet(6)
            }),
            ..Default::default()
        };
        let svc = InferenceService::start(Engine::new(testutil::tiny_model()), cfg).unwrap();
        let pa = svc.submit_for(0, testutil::tiny_image(0)).unwrap();
        std::thread::sleep(Duration::from_millis(5));
        let pb = svc.submit_for(1, testutil::tiny_image(1)).unwrap();
        assert!(pa.wait().is_ok());
        assert_eq!(pb.wait_reply().unwrap_err(), ReplyError::Deadline);
        let snap = svc.shutdown();
        assert_eq!(snap.expired_deadline, 1);
        assert_eq!(snap.classes[1].expired_deadline, 1);
        assert_eq!(snap.classes[0].expired_deadline, 0);
        assert_eq!(snap.classes[0].completed, 1);
    }

    #[test]
    fn tenant_spec_parses_and_rejects() {
        let classes =
            parse_tenant_spec("interactive:cap=64:deadline_ms=20, batchy:cap=256 ,best_effort")
                .unwrap();
        assert_eq!(classes.len(), 3);
        assert_eq!(classes[0].name, "interactive");
        assert_eq!(classes[0].queue_cap, 64);
        assert_eq!(classes[0].default_deadline, Some(Duration::from_millis(20)));
        assert_eq!(classes[1].name, "batchy");
        assert_eq!(classes[1].queue_cap, 256);
        assert_eq!(classes[1].default_deadline, None);
        assert_eq!(classes[2].name, "best_effort");
        assert_eq!(classes[2].queue_cap, 0);
        assert!(parse_tenant_spec("").is_err());
        assert!(parse_tenant_spec(":cap=4").is_err());
        assert!(parse_tenant_spec("a:cap=notanumber").is_err());
        assert!(parse_tenant_spec("a:wat=4").is_err());
    }

    #[test]
    fn shard_count_resolution_clamps_to_workers() {
        // Explicit config wins and clamps; 0 falls through to the env/auto
        // path, which defaults to one shard per worker. (The env read
        // itself is exercised by the CI serving matrix, not here — tests
        // must not mutate process-global env.)
        assert_eq!(resolve_shards(1, 8), 1);
        assert_eq!(resolve_shards(4, 8), 4);
        assert_eq!(resolve_shards(16, 4), 4, "shards clamp to the worker count");
        assert_eq!(resolve_shards(3, 0), 1, "workers floor is 1");
        if std::env::var("CVAPPROX_SHARDS").is_err() {
            assert_eq!(resolve_shards(0, 6), 6, "auto = one shard per worker");
        }
    }
}
