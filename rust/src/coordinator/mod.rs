//! L3 coordinator: a batching inference service over the quantized engine.
//!
//! The paper's contribution is the accelerator datapath (MAC\*/MAC⁺), so the
//! coordinator is the *deployment* shell around it: request queue, dynamic
//! batcher, a **worker pool** (`ServiceConfig::workers`) that fuses each
//! drained batch into one wide GEMM per layer via
//! `Engine::forward_batch_with_scratch`, latency/throughput/occupancy
//! metrics, and the power/energy accounting that converts the [`crate::hw`]
//! cost model + array occupancy into per-inference modeled energy (how the
//! e2e example reports the paper's headline "45% power, <1% loss").
//!
//! The serving plane is **sharded and multi-tenant** (PR 9): requests land
//! on work-stealing queue shards (`CVAPPROX_SHARDS`, auto = one per
//! worker) instead of a single contended lock, and every request carries a
//! tenant/SLO class ([`TenantClass`]) with its own admission bound,
//! default deadline and policy plane. The dynamic batcher is
//! deadline-aware: its fill-wait is capped at the earliest deadline in the
//! batch, so a lone tight-deadline request is served, not expired.
//!
//! The serving policy is **hot-swappable per tenant**: every batch
//! captures an epoch-stamped policy generation of its class's plane
//! ([`crate::nn::PolicySwitch`]), and a [`PolicyInstaller`] (held by that
//! class's [`crate::qos`] governor) can validate, warm and install new
//! generations into a live pool without stalling it — in-flight batches
//! complete on their captured epoch, replies carry it.
//!
//! The serving plane is **supervised and self-healing** (see
//! [`crate::fault`]): workers run their batches under `catch_unwind`, a
//! supervisor thread respawns crashed workers with exponential backoff,
//! cache corruption is checksum-detected / CV-band-alarmed, healed in place
//! and the affected batch replayed, and every accepted request resolves to
//! exactly one reply — `Ok` or a typed [`ReplyError`].
//!
//! * [`service`] — request queue + dynamic batcher + worker pool + hot swap
//! * [`metrics`] — latency histogram/throughput/energy + per-worker accounting

pub mod metrics;
pub mod service;

pub use metrics::{ClassSnapshot, LatencyHistogram, MetricsSnapshot, PowerModel};
pub use service::{
    default_service_workers, InferenceService, Pending, PolicyInstaller, Reply, ReplyError,
    ServiceConfig, TenantClass,
};
