//! L3 coordinator: a batching inference service over the quantized engine.
//!
//! The paper's contribution is the accelerator datapath (MAC\*/MAC⁺), so the
//! coordinator is the *deployment* shell around it: request queue, dynamic
//! batcher, a **worker pool** (`ServiceConfig::workers`) that fuses each
//! drained batch into one wide GEMM per layer via
//! `Engine::forward_batch_with_scratch`, latency/throughput/occupancy
//! metrics, and the power/energy accounting that converts the [`crate::hw`]
//! cost model + array occupancy into per-inference modeled energy (how the
//! e2e example reports the paper's headline "45% power, <1% loss").
//!
//! * [`service`] — request queue + dynamic batcher + worker pool
//! * [`metrics`] — latency/throughput/energy + per-worker accounting

pub mod metrics;
pub mod service;

pub use metrics::{MetricsSnapshot, PowerModel};
pub use service::{default_service_workers, InferenceService, ServiceConfig};
