//! Control-variate constants and epilogue (paper §3).
//!
//! For a filter row W[0..k) and family/m, the MAC⁺ column adds
//! V = C·ΣX + C₀ to the accumulated approximate convolution:
//!
//! | family     | x_j              | C            | C₀                      |
//! |------------|------------------|--------------|--------------------------|
//! | perforated | A_j mod 2^m      | E[W_j]       | 0          (eqs. 18/21) |
//! | recursive  | A_j mod 2^m      | E[W_j mod 2^m]| 0         (eqs. 29/32) |
//! | truncated  | OR(A_j[m−1:0])   | E[Ŵ_j]       | 2^−m·ΣŴ_j (eqs. 25/26/28)|
//!
//! C and C₀ are carried in **Q.4 fixed point** (4 fractional bits): the
//! hardware MAC⁺ multiplier is a narrow exact multiplier (paper §4.4), and 4
//! fractional bits keep the rounding error of V below ±0.5 LSB of the
//! accumulator for every array size the paper sweeps. The Q.4 choice is
//! ablated in `benches/ablation.rs`. These integers match the python side
//! (`kernels/ref.cv_constants`) bit-for-bit.

use crate::approx::{comp_low, w_hat_pos_q1, w_hat_q1, xvar, xvar_pol, Family, Polarity};

/// Fixed-point fractional bits for C / C₀ / V.
pub const CV_FRAC_BITS: u32 = 4;
const Q: i64 = 1 << CV_FRAC_BITS;

/// Per-filter control-variate constants in Q.4.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CvConstants {
    pub c_q4: i64,
    pub c0_q4: i64,
}

/// Round-to-nearest division (half away from zero), `den > 0`.
///
/// The numerator used to be assumed non-negative (true for Σ of uint8
/// weights), but policy-driven constants can be built from arbitrary rows —
/// e.g. effective signed weights `w − zp_w` — where truncating division
/// rounded negative halves toward zero. Matches `round_half_away` / the
/// python reference for every sign.
#[inline]
fn div_round(num: i64, den: i64) -> i64 {
    debug_assert!(den > 0);
    if num >= 0 {
        (num + den / 2) / den
    } else {
        -((-num + den / 2) / den)
    }
}

/// Compute C and C₀ for one filter row of uint8 weights.
///
/// `k_valid` is the true filter size; pass it when `w` is zero-padded (the
/// averages divide by k, and padded zeros must not dilute them).
pub fn constants(family: Family, m: u32, w: &[u8], k_valid: usize) -> CvConstants {
    debug_assert!(k_valid <= w.len() || w.is_empty());
    if family == Family::Exact || m == 0 {
        return CvConstants::default();
    }
    let k = k_valid as i64;
    if k == 0 {
        return CvConstants::default();
    }
    let num: i64 = match family {
        Family::Perforated => w.iter().map(|&x| x as i64).sum(),
        Family::Recursive => {
            let mask = (1i64 << m) - 1;
            w.iter().map(|&x| (x as i64) & mask).sum()
        }
        // num = Σ 2·Ŵ_j (Q.1 per weight)
        Family::Truncated => w.iter().map(|&x| w_hat_q1(x, m) as i64).sum(),
        Family::Exact => unreachable!(),
    };
    let den = k * if family == Family::Truncated { 2 } else { 1 };
    let c_q4 = div_round(num * Q, den);
    let c0_q4 = if family == Family::Truncated {
        // C₀ = 2^−m · ΣŴ = num / 2^{m+1}
        div_round(num * Q, 1i64 << (m + 1))
    } else {
        0
    };
    CvConstants { c_q4, c0_q4 }
}

/// C and C₀ for one filter row of a `(family, m, polarity)` point.
///
/// `Neg` is [`constants`]. `Pos` points overestimate — their signed error
/// is the exact mirror of the matching magnitude statistic — so the
/// constants are the negated means of the *complement* quantities, and
/// V = C·ΣX + C₀ comes out negative, pulling the overestimating
/// accumulator back down:
///
/// | family     | x_j (Pos)          | C (Pos)              | C₀ (Pos)          |
/// |------------|--------------------|----------------------|-------------------|
/// | perforated | comp(A_j mod 2^m)  | −E[W_j]              | 0                 |
/// | recursive  | comp(A_j mod 2^m)  | −E[comp(W_j mod 2^m)]| 0                 |
/// | truncated  | OR(A_j[m−1:0])     | −E[Ŵ⁺_j]             | −2^−m·ΣŴ⁺_j       |
pub fn constants_pol(
    family: Family,
    pol: Polarity,
    m: u32,
    w: &[u8],
    k_valid: usize,
) -> CvConstants {
    if pol == Polarity::Neg {
        return constants(family, m, w, k_valid);
    }
    debug_assert!(k_valid <= w.len() || w.is_empty());
    if family == Family::Exact || m == 0 {
        return CvConstants::default();
    }
    let k = k_valid as i64;
    if k == 0 {
        return CvConstants::default();
    }
    let num: i64 = match family {
        Family::Perforated => w.iter().map(|&x| x as i64).sum(),
        Family::Recursive => w.iter().map(|&x| comp_low(x as i32, m) as i64).sum(),
        // num = Σ 2·Ŵ⁺_j (Q.1 per weight)
        Family::Truncated => w.iter().map(|&x| w_hat_pos_q1(x, m) as i64).sum(),
        Family::Exact => unreachable!(),
    };
    let den = k * if family == Family::Truncated { 2 } else { 1 };
    let c_q4 = -div_round(num * Q, den);
    let c0_q4 = if family == Family::Truncated {
        -div_round(num * Q, 1i64 << (m + 1))
    } else {
        0
    };
    CvConstants { c_q4, c0_q4 }
}

/// Per-filter constants for a whole layer: row f of `w` is
/// `w[f*k..(f+1)*k]`. This is the **plan-building** entry point — C/C₀ are
/// functions of the static weights only, so callers cache the result per
/// (layer, family, m) instead of recomputing inside every GEMM
/// (see [`crate::nn::plan::LayerPlan`]).
pub fn constants_for_rows(
    family: Family,
    m: u32,
    w: &[u8],
    m_rows: usize,
    k: usize,
) -> Vec<CvConstants> {
    debug_assert_eq!(w.len(), m_rows * k);
    (0..m_rows).map(|f| constants(family, m, &w[f * k..(f + 1) * k], k)).collect()
}

/// Polarity-aware [`constants_for_rows`] with an explicit `k_valid`: paired
/// partition plans pass the partition population (their weight panels are
/// zero off-partition, and the averages must divide by the partition size,
/// not the full reduction length).
pub fn constants_pol_for_rows(
    family: Family,
    pol: Polarity,
    m: u32,
    w: &[u8],
    m_rows: usize,
    k: usize,
    k_valid: usize,
) -> Vec<CvConstants> {
    debug_assert_eq!(w.len(), m_rows * k);
    (0..m_rows)
        .map(|f| constants_pol(family, pol, m, &w[f * k..(f + 1) * k], k_valid))
        .collect()
}

/// ΣX over an activation column.
#[inline]
pub fn sum_x(family: Family, m: u32, activations: &[u8]) -> i64 {
    activations.iter().map(|&a| xvar(family, a, m) as i64).sum()
}

/// Polarity-aware ΣX over an activation column.
#[inline]
pub fn sum_x_pol(family: Family, pol: Polarity, m: u32, activations: &[u8]) -> i64 {
    activations.iter().map(|&a| xvar_pol(family, pol, a, m) as i64).sum()
}

/// The MAC⁺ epilogue: V = round((C·ΣX + C₀) / 2^4), added to the accumulator.
#[inline]
pub fn v_term(c: &CvConstants, sum_x: i64) -> i64 {
    let v_q4 = c.c_q4 * sum_x + c.c0_q4;
    (v_q4 + Q / 2) >> CV_FRAC_BITS
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::{am, err};
    use crate::util::rng::Rng;
    use crate::util::stats::Welford;

    /// Simulate one convolution: returns (exact, approx_raw, approx_cv).
    fn conv(family: Family, m: u32, w: &[u8], a: &[u8]) -> (i64, i64, i64) {
        let exact: i64 = w.iter().zip(a).map(|(&w, &a)| (w as i64) * (a as i64)).sum();
        let am_acc: i64 =
            w.iter().zip(a).map(|(&w, &a)| am(family, w, a, m) as i64).sum();
        let c = constants(family, m, w, w.len());
        let sx = sum_x(family, m, a);
        (exact, am_acc, am_acc + v_term(&c, sx))
    }

    #[test]
    fn cv_nullifies_mean_and_cuts_variance_all_families() {
        // The paper's central claims (eqs. 20/22/28), checked per family/m.
        let mut rng = Rng::new(0xC0);
        let k = 64;
        for family in Family::APPROX {
            for &m in family.paper_levels() {
                // trained-like weights: concentrated (paper Fig. 4)
                let w: Vec<u8> = (0..k).map(|_| rng.u8_normal(128.0, 22.0)).collect();
                let mut raw = Welford::new();
                let mut cv = Welford::new();
                for _ in 0..3000 {
                    let a: Vec<u8> = (0..k).map(|_| rng.u8()).collect();
                    let (ex, r, c) = conv(family, m, &w, &a);
                    raw.push((ex - r) as f64);
                    cv.push((ex - c) as f64);
                }
                assert!(
                    cv.mean().abs() <= 0.05 * raw.mean().abs() + 2.0,
                    "{} m={m}: cv mean {} raw mean {}",
                    family.name(), cv.mean(), raw.mean()
                );
                assert!(
                    cv.variance() < raw.variance(),
                    "{} m={m}: var not reduced", family.name()
                );
            }
        }
    }

    #[test]
    fn cv_nullifies_mean_for_positive_polarity_too() {
        // The mirrored constants correct the overestimating points exactly
        // like the originals correct the underestimating ones.
        use crate::approx::{am_pol, Polarity};
        let mut rng = Rng::new(0xC1);
        let k = 64;
        for family in Family::APPROX {
            let m = family.paper_levels()[1];
            let w: Vec<u8> = (0..k).map(|_| rng.u8_normal(128.0, 22.0)).collect();
            let c = constants_pol(family, Polarity::Pos, m, &w, k);
            assert!(c.c_q4 <= 0, "{}: pos C must be non-positive", family.name());
            let mut raw = Welford::new();
            let mut cv = Welford::new();
            for _ in 0..3000 {
                let a: Vec<u8> = (0..k).map(|_| rng.u8()).collect();
                let exact: i64 =
                    w.iter().zip(&a).map(|(&w, &a)| (w as i64) * (a as i64)).sum();
                let am_acc: i64 = w
                    .iter()
                    .zip(&a)
                    .map(|(&w, &a)| am_pol(family, Polarity::Pos, w, a, m) as i64)
                    .sum();
                let sx = sum_x_pol(family, Polarity::Pos, m, &a);
                raw.push((exact - am_acc) as f64);
                cv.push((exact - (am_acc + v_term(&c, sx))) as f64);
            }
            assert!(raw.mean() < 0.0, "{}: pos raw error must overestimate", family.name());
            assert!(
                cv.mean().abs() <= 0.05 * raw.mean().abs() + 2.0,
                "{} m={m}: cv mean {} raw mean {}",
                family.name(),
                cv.mean(),
                raw.mean()
            );
            assert!(cv.variance() < raw.variance(), "{} m={m}", family.name());
        }
    }

    #[test]
    fn pos_constants_mirror_neg_for_perforated() {
        use crate::approx::Polarity;
        let mut rng = Rng::new(0xC2);
        let w: Vec<u8> = (0..40).map(|_| rng.u8()).collect();
        for m in [1u32, 2, 3] {
            let neg = constants_pol(Family::Perforated, Polarity::Neg, m, &w, 40);
            let pos = constants_pol(Family::Perforated, Polarity::Pos, m, &w, 40);
            // Same Σw numerator, negated: exact mirror.
            assert_eq!(pos.c_q4, -neg.c_q4, "m={m}");
            assert_eq!(pos.c0_q4, 0);
        }
        // Neg delegation: constants_pol(Neg) == constants.
        let a = constants_pol(Family::Truncated, Polarity::Neg, 5, &w, 40);
        let b = constants(Family::Truncated, 5, &w, 40);
        assert_eq!(a, b);
        // k_valid == 0 (an empty pair partition) is a clean zero.
        let z = constants_pol(Family::Perforated, Polarity::Pos, 2, &[], 0);
        assert_eq!(z, CvConstants::default());
    }

    #[test]
    fn div_round_is_half_away_from_zero_for_both_signs() {
        // Positive halves round up (unchanged behaviour)...
        assert_eq!(div_round(5, 2), 3); // 2.5 -> 3
        assert_eq!(div_round(4, 2), 2);
        assert_eq!(div_round(7, 3), 2); // 2.33 -> 2
        assert_eq!(div_round(0, 4), 0);
        // ...and negative halves round away from zero, not toward it (the
        // old `(num + den/2) / den` gave -5/2 -> -2 via truncation).
        assert_eq!(div_round(-5, 2), -3); // -2.5 -> -3
        assert_eq!(div_round(-4, 2), -2);
        assert_eq!(div_round(-7, 3), -2); // -2.33 -> -2
        assert_eq!(div_round(-1, 2), -1); // -0.5 -> -1
        assert_eq!(div_round(1, 2), 1); //  0.5 -> 1
        // Pinned against the f64 reference on a sweep of both signs.
        for num in -50i64..=50 {
            for den in 1i64..=7 {
                let want = crate::nn::engine::round_half_away(num as f64 / den as f64)
                    as i64;
                assert_eq!(div_round(num, den), want, "{num}/{den}");
            }
        }
    }

    #[test]
    fn perforated_c_is_mean_weight() {
        let w: Vec<u8> = vec![10, 20, 30, 40];
        let c = constants(Family::Perforated, 2, &w, 4);
        assert_eq!(c.c_q4, 25 * 16);
        assert_eq!(c.c0_q4, 0);
    }

    #[test]
    fn recursive_c_is_mean_low_part() {
        let w: Vec<u8> = vec![0b1111_1101, 0b0000_0011]; // low 2 bits: 1, 3
        let c = constants(Family::Recursive, 2, &w, 2);
        assert_eq!(c.c_q4, 2 * 16);
    }

    #[test]
    fn truncated_c0_matches_eq28() {
        let mut rng = Rng::new(5);
        let w: Vec<u8> = (0..32).map(|_| rng.u8()).collect();
        let m = 5;
        let c = constants(Family::Truncated, m, &w, 32);
        let sum_what_x2: i64 = w.iter().map(|&x| w_hat_q1(x, m) as i64).sum();
        // C0 = sum_what / 2^m, in Q.4: sum_what_x2 * 16 / 2^(m+1)
        let expect = (sum_what_x2 * 16 + (1 << m)) >> (m + 1);
        assert_eq!(c.c0_q4, expect);
    }

    #[test]
    fn zero_padding_with_k_valid_matches_unpadded() {
        let mut rng = Rng::new(6);
        let w: Vec<u8> = (0..20).map(|_| rng.u8()).collect();
        let mut wp = w.clone();
        wp.extend(std::iter::repeat(0u8).take(44));
        for family in [Family::Perforated, Family::Recursive, Family::Truncated] {
            let a = constants(family, 3, &w, 20);
            let b = constants(family, 3, &wp, 20);
            assert_eq!(a, b, "{}", family.name());
        }
    }

    #[test]
    fn constants_for_rows_matches_per_row() {
        let mut rng = Rng::new(11);
        let (m_rows, k) = (5, 18);
        let w: Vec<u8> = (0..m_rows * k).map(|_| rng.u8()).collect();
        for family in Family::APPROX {
            let all = constants_for_rows(family, 3, &w, m_rows, k);
            assert_eq!(all.len(), m_rows);
            for f in 0..m_rows {
                assert_eq!(all[f], constants(family, 3, &w[f * k..(f + 1) * k], k));
            }
        }
    }

    #[test]
    fn exact_family_has_zero_v() {
        let c = constants(Family::Exact, 0, &[1, 2, 3], 3);
        assert_eq!(v_term(&c, 12345), 0);
    }

    #[test]
    fn c_optimality_eq21() {
        // Var(eps - C·x) is minimized at C = E[W] (perforated).
        let mut rng = Rng::new(0x21);
        let k = 48;
        let m = 2;
        let w: Vec<u8> = (0..k).map(|_| rng.u8_normal(110.0, 25.0)).collect();
        let var_with_c = |c_q4: i64| {
            let mut acc = Welford::new();
            let mut r = Rng::new(1);
            for _ in 0..2000 {
                let a: Vec<u8> = (0..k).map(|_| r.u8()).collect();
                let eps: i64 = w.iter().zip(&a)
                    .map(|(&w, &a)| err(Family::Perforated, w, a, m) as i64)
                    .sum();
                let sx = sum_x(Family::Perforated, m, &a);
                let v = (c_q4 * sx + 8) >> 4;
                acc.push((eps - v) as f64);
            }
            acc.variance()
        };
        let c_opt = constants(Family::Perforated, m, &w, k).c_q4;
        let v_opt = var_with_c(c_opt);
        for dc in [-320, -160, 160, 320] {
            assert!(var_with_c(c_opt + dc) > v_opt, "dc={dc}");
        }
    }

    #[test]
    fn q4_rounding_error_is_small() {
        // |V_q4 - V_real| < k/2 LSB-equivalents even for the largest array.
        let mut rng = Rng::new(9);
        let k = 256;
        let w: Vec<u8> = (0..k).map(|_| rng.u8()).collect();
        let a: Vec<u8> = (0..k).map(|_| rng.u8()).collect();
        let c = constants(Family::Perforated, 3, &w, k);
        let sx = sum_x(Family::Perforated, 3, &a);
        let c_real = w.iter().map(|&x| x as f64).sum::<f64>() / k as f64;
        let v_real = c_real * sx as f64;
        let v_fix = v_term(&c, sx) as f64;
        assert!((v_fix - v_real).abs() <= sx as f64 / 32.0 + 1.0);
    }
}
