//! Stub of the `xla` (xla-rs 0.5.x) API surface used by `cvapprox::runtime`.
//!
//! Every constructor that would touch PJRT returns [`Error`] explaining the
//! stub, so code gated behind the `pjrt` feature compiles (and fails fast at
//! runtime with a clear message) in environments where the real XLA native
//! libraries are unavailable. The real crate is a drop-in replacement: the
//! method names/signatures below match what `runtime::pjrt` calls.

use std::fmt;

/// Error type mirroring `xla::Error`; implements `std::error::Error` so it
/// converts into `anyhow::Error` through `?` like the real crate's errors.
#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    fn stub(what: &str) -> Error {
        Error {
            msg: format!(
                "{what}: xla stub crate in use — the real PJRT runtime is not \
                 vendored in this build (see rust/vendor/xla-stub)"
            ),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Parsed HLO module (stub carries nothing).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::stub("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation built from a proto.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Host literal (dense array value).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1(_data: &[i32]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::stub("Literal::reshape"))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::stub("Literal::to_tuple"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::stub("Literal::to_vec"))
    }
}

/// Device-resident buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stub("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client handle.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::stub("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::stub("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_fast_with_clear_message() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(err.to_string().contains("xla stub"), "{err}");
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
