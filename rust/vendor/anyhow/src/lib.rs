//! Minimal, API-compatible subset of the `anyhow` crate for offline builds.
//!
//! Provides exactly the surface `cvapprox` uses:
//! * [`Error`] — a flattened message-chain error (contexts are joined
//!   eagerly with `": "`, so `{}` and `{:#}` both render the full chain).
//! * [`Result<T>`] with the error type defaulted.
//! * [`anyhow!`], [`bail!`], [`ensure!`] macros.
//! * [`Context`] for `Result<T, E: std::error::Error>`, `Result<T, Error>`
//!   and `Option<T>`.
//! * A blanket `From<E: std::error::Error + Send + Sync + 'static>` so `?`
//!   converts std errors (io, parse, recv, ...) like the real crate.
//!
//! Like real `anyhow`, [`Error`] deliberately does **not** implement
//! `std::error::Error` — that is what makes the blanket `From` coherent.

use std::fmt;

/// Flattened error: the full context chain as one string.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Wrap with an outer context (`"ctx: inner"`), mirroring anyhow's
    /// `{:#}` chain rendering.
    pub fn context<C: fmt::Display>(self, ctx: C) -> Error {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Include source chain segments the way `{:#}` would.
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// `anyhow::Result<T>` with the error type defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (and to `None`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(&$err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] if the condition fails.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            $crate::bail!("condition failed: {}", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !$cond {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_num(s: &str) -> Result<i32> {
        let n: i32 = s.parse()?; // From<ParseIntError>
        Ok(n)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parse_num("42").unwrap(), 42);
        assert!(parse_num("nope").is_err());
    }

    #[test]
    fn context_chains_messages() {
        let e = parse_num("x").context("reading config").unwrap_err();
        let s = format!("{e:#}");
        assert!(s.starts_with("reading config: "), "{s}");
    }

    #[test]
    fn context_on_option_and_own_result() {
        let n: Option<u8> = None;
        assert!(n.context("missing").is_err());
        let r: Result<u8> = Err(anyhow!("inner"));
        let e = r.with_context(|| "outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x > 100 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert!(f(-1).is_err());
        assert!(f(101).is_err());
        assert_eq!(f(7).unwrap(), 7);
    }

    #[test]
    fn anyhow_macro_accepts_display_values() {
        let e = anyhow!(String::from("already a message"));
        assert_eq!(e.to_string(), "already a message");
    }
}
