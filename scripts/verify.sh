#!/usr/bin/env bash
# Tier-1 verification gate + perf smoke.
#
#   scripts/verify.sh          # build + tests + gemm_throughput smoke
#   SKIP_BENCH=1 scripts/verify.sh   # tier-1 only
#
# The bench smoke runs with CVAPPROX_BENCH_QUICK=1 (short budgets) and
# leaves BENCH_gemm_throughput.json in the repo root for perf tracking.

set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

if [ "${SKIP_BENCH:-0}" != "1" ]; then
    echo "== perf smoke: gemm_throughput (quick budgets) =="
    CVAPPROX_BENCH_QUICK=1 cargo bench -p cvapprox --bench gemm_throughput
    if [ -f BENCH_gemm_throughput.json ]; then
        echo "== BENCH_gemm_throughput.json written =="
    else
        echo "error: bench did not write BENCH_gemm_throughput.json" >&2
        exit 1
    fi
fi

echo "== verify OK =="
