#!/usr/bin/env bash
# Tier-1 verification gate + perf/serving smoke.
#
#   scripts/verify.sh          # build + tests + bench smokes
#   SKIP_BENCH=1 scripts/verify.sh   # tier-1 + serving tests only
#
# The bench smokes run with CVAPPROX_BENCH_QUICK=1 (short budgets) and
# leave BENCH_gemm_throughput.json / BENCH_serving.json in the repo root
# for cross-PR perf tracking.

set -euo pipefail
cd "$(dirname "$0")/.."

# Hang watchdog: the fault/chaos suites must never wedge CI, so the
# long-running cargo invocations get GNU timeout when available (SIGTERM
# at WATCHDOG_SECS, SIGKILL 15 s later). No-op where timeout is missing.
WATCHDOG_SECS="${WATCHDOG_SECS:-900}"
run_guarded() {
    if command -v timeout >/dev/null 2>&1; then
        timeout -k 15 "$WATCHDOG_SECS" "$@"
    else
        "$@"
    fi
}

# Bench artifact gate: every bench smoke must leave its JSON at the repo
# root (that is where CI's upload step and cross-PR perf tracking look),
# non-empty and parseable — a bench that "passed" but wrote a truncated or
# empty artifact is a silent CI regression, so fail loudly here instead.
require_artifact() {
    local f="$1"
    if [ ! -f "$f" ]; then
        echo "error: bench did not write $f (expected at repo root)" >&2
        exit 1
    fi
    if [ ! -s "$f" ]; then
        echo "error: $f is empty" >&2
        exit 1
    fi
    if command -v python3 >/dev/null 2>&1; then
        if ! python3 -c "import json,sys; json.load(open(sys.argv[1]))" "$f"; then
            echo "error: $f is not valid JSON" >&2
            exit 1
        fi
    fi
    echo "== $f written ($(wc -c <"$f") bytes) =="
}

echo "== tier-1: cargo build --release =="
cargo build --release

# Project-invariant static analysis (rust/src/analyze/): a hard gate, run
# right after the build and before anything slow, so an invariant
# violation (bare lock().unwrap(), off-contract atomic ordering, hot-path
# panic, wall-clock in a deterministic module, env-var registry drift)
# fails fast. Writes LINT_report.json for the CI artifact upload. The
# python mirror (scripts/srclint_mirror.py) must agree rule-for-rule.
echo "== srclint: project invariants (R1-R5) =="
./target/release/cvapprox srclint --json LINT_report.json

# NSGA machinery mirror: scripts/search_mirror.py independently re-derives
# the non-dominated fronts, crowding distances, survivor selection and
# hypervolume from the checked-in fixture
# (rust/tests/fixtures/search_front.json) — the same numbers the Rust
# search suite pins — so a drift in either transliteration fails fast.
if command -v python3 >/dev/null 2>&1; then
    echo "== search mirror: NSGA fixture cross-check =="
    python3 scripts/search_mirror.py
else
    echo "warning: python3 not installed; skipping search mirror" >&2
fi

echo "== tier-1: cargo test -q =="
run_guarded cargo test -q

# The hermetic golden suite must EXECUTE (not skip): it runs on the
# checked-in rust/tests/hermetic mini-artifacts, so a pass here proves the
# engine still matches the python reference bit-for-bit without
# `make artifacts`. (Included in `cargo test -q` above; run by name so a
# silent skip regression is visible in the log.) The paired tier and the
# differential harness (every engine tier bit-identical on every
# family × m × polarity point) run the same way.
echo "== tier-1: hermetic golden vectors (incl. paired tier) =="
cargo test -q -p cvapprox --test golden hermetic

echo "== tier-1: differential engine harness =="
cargo test -q -p cvapprox --test differential

# Kernel-backend matrix: the same differential + golden suites with the
# GEMM backend pinned each way. CVAPPROX_KERNEL resolves once per process,
# so each pin needs its own cargo invocation. `simd` is valid on every
# host — without AVX2 it runs its portable chunked lanes (bit-identical by
# the same tests); the warning just makes the reduced coverage visible.
if ! grep -q avx2 /proc/cpuinfo 2>/dev/null; then
    echo "warning: no AVX2 on this host — CVAPPROX_KERNEL=simd exercises the portable lanes only" >&2
fi
for kernel in scalar simd; do
    echo "== kernel matrix: differential + golden @ CVAPPROX_KERNEL=$kernel =="
    run_guarded env CVAPPROX_KERNEL="$kernel" cargo test -q -p cvapprox --test differential
    run_guarded env CVAPPROX_KERNEL="$kernel" cargo test -q -p cvapprox --test golden hermetic
done

# The coordinator worker pool must behave identically at 1 worker and at a
# small pool (bit-exact replies, batch fusion, clean shutdown, no panics).
# The burst/NaN/default-config service tests size their pools from
# CVAPPROX_SERVICE_WORKERS, so these two runs genuinely vary the pool.
echo "== serving smoke: coordinator tests at 1 worker =="
run_guarded env CVAPPROX_SERVICE_WORKERS=1 cargo test -q -p cvapprox --lib coordinator

echo "== serving smoke: coordinator tests at 4 workers =="
run_guarded env CVAPPROX_SERVICE_WORKERS=4 cargo test -q -p cvapprox --lib coordinator

# Sharded-queue smoke: the same suite with the shard count pinned to the
# legacy single-queue shape and to one-shard-per-worker. CVAPPROX_SHARDS=1
# must be bit-for-bit the pre-PR-9 behavior; 4 exercises work stealing on
# every pooled test.
echo "== serving smoke: coordinator tests at 4 workers, 1 shard (legacy queue) =="
run_guarded env CVAPPROX_SERVICE_WORKERS=4 CVAPPROX_SHARDS=1 \
    cargo test -q -p cvapprox --lib coordinator

echo "== serving smoke: coordinator tests at 4 workers, 4 shards =="
run_guarded env CVAPPROX_SERVICE_WORKERS=4 CVAPPROX_SHARDS=4 \
    cargo test -q -p cvapprox --lib coordinator

if [ "${SKIP_BENCH:-0}" != "1" ]; then
    echo "== perf smoke: gemm_throughput (quick budgets) =="
    CVAPPROX_BENCH_QUICK=1 cargo bench -p cvapprox --bench gemm_throughput
    require_artifact BENCH_gemm_throughput.json

    echo "== perf smoke: serving (quick budgets) =="
    CVAPPROX_BENCH_QUICK=1 cargo bench -p cvapprox --bench serving
    require_artifact BENCH_serving.json

    # Heterogeneous-policy serving: hermetic (no artifacts needed). The
    # bench itself asserts the acceptance claim — the greedy mixed policy
    # beats every uniform point at equal-or-lower synthetic accuracy loss —
    # and that pool replies are bit-identical to the per-image policy
    # forward, so a nonzero exit here is a real regression.
    echo "== policy smoke: policy_serving (quick budgets) =="
    CVAPPROX_BENCH_QUICK=1 cargo bench -p cvapprox --bench policy_serving
    require_artifact BENCH_policy.json

    # Positive/negative pairing: the bench asserts the paired ladder search
    # dominates-or-matches the mixed policy on the (power, loss) plane
    # (strictly, on the hermetic set) and that pool replies are
    # bit-identical to per-image paired forwards.
    echo "== pairing smoke: paired_policy (quick budgets) =="
    CVAPPROX_BENCH_QUICK=1 cargo bench -p cvapprox --bench paired_policy
    require_artifact BENCH_paired.json

    # Adaptive QoS: a bursty trace must drive the governor down the ladder
    # and back up (>= 2 transitions recorded in BENCH_qos.json), with every
    # reply bit-identical to the static forward of its epoch's rung; the
    # bench asserts all of it and emits the ladder artifact too.
    echo "== qos smoke: qos_adaptive (quick budgets) =="
    CVAPPROX_BENCH_QUICK=1 cargo bench -p cvapprox --bench qos_adaptive
    require_artifact BENCH_qos.json

    # Chaos suite: deterministic fault injection at two fixed seeds. The
    # bench asserts the robustness contract itself (exactly one reply per
    # request, zero silent corruption vs the fault-free reference, bounded
    # time-to-heal, typed overload/deadline errors), so a nonzero exit is a
    # real regression. CVAPPROX_FAULT_SEED is deliberately scoped to these
    # two invocations only — ServiceConfig::default() reads it, and nothing
    # else in this script should run in chaos mode.
    for seed in 1002 7707; do
        echo "== chaos smoke: fault injection @ seed $seed (quick budgets) =="
        run_guarded env CVAPPROX_BENCH_QUICK=1 CVAPPROX_FAULT_SEED="$seed" \
            cargo bench -p cvapprox --bench chaos
    done
    require_artifact BENCH_fault.json

    # Co-design search: the seeded NSGA-II genome/assignment search vs the
    # greedy ladder. The bench asserts a byte-identical SEARCH_pareto.json
    # at 1 and 4 workers, strict dominance over the greedy-paired rung, a
    # hypervolume no smaller than the greedy ladder's, and a power-monotone
    # merged ladder with at least one searched rung installed — so a
    # nonzero exit here is a real regression.
    echo "== search smoke: codesign_search (quick budgets) =="
    run_guarded env CVAPPROX_BENCH_QUICK=1 \
        cargo bench -p cvapprox --bench codesign_search
    require_artifact BENCH_search.json
    require_artifact SEARCH_pareto.json
fi

# Lint gates (after the correctness gates, so a style failure never masks a
# real regression in the log): formatting must be rustfmt-clean and clippy
# must be warning-free. CVAPPROX_SKIP_LINT=1 skips both (for toolchains
# without the components).
if [ "${CVAPPROX_SKIP_LINT:-0}" != "1" ]; then
    if cargo fmt --version >/dev/null 2>&1; then
        echo "== lint: cargo fmt --check =="
        cargo fmt --check
    else
        echo "warning: rustfmt not installed; skipping fmt gate" >&2
    fi
    if cargo clippy --version >/dev/null 2>&1; then
        echo "== lint: cargo clippy -D warnings =="
        # The GEMM/epilogue plumbing passes layer geometry explicitly
        # (rows/k/n/zp/bias/scratch/threads), so the arity and index-loop
        # style lints are allowed as established idiom; everything else is
        # denied.
        cargo clippy --workspace --all-targets -- -D warnings \
            -A clippy::too_many_arguments -A clippy::needless-range-loop
    else
        echo "warning: clippy not installed; skipping clippy gate" >&2
    fi
fi

# Optional deep concurrency checks (miri + ThreadSanitizer). Off by
# default — they need nightly components and a long budget — and run in
# their own CI job; CVAPPROX_CONCURRENCY_CHECKS=1 opts in locally.
if [ "${CVAPPROX_CONCURRENCY_CHECKS:-0}" = "1" ]; then
    bash scripts/concurrency_checks.sh
fi

echo "== verify OK =="
