#!/usr/bin/env python3
"""Generate the checked-in hermetic mini-artifacts under rust/tests/hermetic/.

Purpose: rust/tests/golden.rs must execute for real in CI — not print
"skipping" — without `make artifacts` (slow, jax training) or network access.
This script builds a small deterministic synthetic model + dataset and runs
the repo's *python reference* quantized forward (compile/model.py — the
implementation the rust engine mirrors bit-for-bit) to produce golden
vectors for every (family, m, use_cv) point of the paper grid:

  rust/tests/hermetic/models/hermnet_hsynth.cvm
  rust/tests/hermetic/data/hsynth_test.cvd        (64 images, 10 classes)
  rust/tests/hermetic/golden/*.gv                 (38 vectors)

Everything is seeded and integer/float64-deterministic, so regenerating
produces byte-identical files. Labels are the exact-forward argmax (last-max
tie rule, matching the rust coordinator's argmax), so the exact design
scores 100% on the hermetic set and approximate designs measure a real,
deterministic accuracy loss — which is what benches/policy_serving.rs and
the layerwise tests evaluate against.

Run from the repo root:  python3 scripts/gen_hermetic_golden.py
(needs numpy; imports the repo's python/compile package)
"""

from __future__ import annotations

import pathlib
import sys

import numpy as np

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "python"))

from compile import export, quant  # noqa: E402
from compile.model import QuantModel, approx_gemm, infer_shapes  # noqa: E402
from compile.nets import Node  # noqa: E402

OUT = REPO / "rust/tests/hermetic"
MODEL_NAME = "hermnet_hsynth"  # dataset stem parses to "hsynth"
N_IMAGES = 64
N_CALIB = 32
IN_SHAPE = (8, 8, 3)
# Seed chosen (swept 0..15) so the hermetic set differentiates designs:
# exact scores 1.0, every uniform (family, m) grid point loses accuracy
# (0.94 .. 0.55), and the greedy layerwise search finds a mixed policy
# (conv1 at m=3, ~40% of MACs, rest exact) with zero loss — i.e. a mixed
# policy that dominates the whole uniform grid. The rust layerwise tests
# and benches/policy_serving.rs assert exactly this structure.
SEED = 3
# Per-class spread of the dense rows: rows share one concentrated base row
# (common-mode approximation error cancels in argmax) plus a small delta
# that sets the logit margins the approximation noise competes with.
DENSE_DELTA_SIGMA = 5.0


def build_nodes() -> list[Node]:
    """input(8,8,3) -> conv3x3(8) -> conv3x3 g2 (8) -> shuffle(2) ->
    maxpool -> conv1x1(16) -> gap -> dense(10)."""
    return [
        Node("input"),
        Node("conv", [0], cout=8, k=3, stride=1, pad=1, groups=1, relu=True),
        Node("conv", [1], cout=8, k=3, stride=1, pad=1, groups=2, relu=True),
        Node("shuffle", [2], groups=2),
        Node("maxpool", [3], k=2, stride=2),
        Node("conv", [4], cout=16, k=1, stride=1, pad=0, groups=1, relu=True),
        Node("gap", [5]),
        Node("dense", [6], nout=10, relu=False),
    ]


def synth_weights(nodes, shapes, rng) -> dict:
    """Trained-net-like uint8 weights: concentrated around the zero point
    (paper Fig. 4) so C = E[W] is an effective control variate. Dense rows
    share a base row plus a small per-class delta (see DENSE_DELTA_SIGMA)."""
    weights = {}
    for i, n in enumerate(nodes):
        if n.op == "conv":
            cin = shapes[n.inputs[0]][2] // n.groups
            kdim = n.k * n.k * cin
            cout = n.cout
            w = rng.normal(128.0, 22.0, size=(cout, kdim))
        elif n.op == "dense":
            kdim = int(np.prod(shapes[n.inputs[0]]))
            cout = n.nout
            base = rng.normal(128.0, 22.0, size=(1, kdim))
            w = base + rng.normal(0.0, DENSE_DELTA_SIGMA, size=(cout, kdim))
        else:
            continue
        w_q = np.clip(np.rint(w), 0, 255).astype(np.uint8)
        b_q = rng.integers(-400, 401, size=cout).astype(np.int32)
        weights[i] = {
            "w_q": w_q,
            "b_q": b_q,
            "s_w": float(np.float32(0.01)),
            "zp_w": 128,
        }
    return weights


def calibrate(nodes, shapes, weights, calib_imgs) -> list[tuple[float, int]]:
    """Sequentially choose per-node (scale, zp): MAC layers from the min/max
    of their exact-accumulator real values over the calib batch (post-ReLU
    observation, like the float calibrator); passthrough ops (maxpool, gap,
    shuffle) keep their input's quantization domain."""
    out_q: list[tuple[float, int]] = [(quant.INPUT_SCALE, 0)] * len(nodes)
    # Per-image forward, filling out_q before each node is first consumed.
    for i, n in enumerate(nodes):
        if n.op in ("conv", "dense"):
            wrec = weights[i]
            s_in, zp_in = out_q[n.inputs[0]]
            los, his = [], []
            for img in calib_imgs:
                outs = forward_until(nodes, shapes, weights, out_q, img, i)
                x = outs[n.inputs[0]]
                acc = mac_accumulator(n, shapes[i], wrec, x, zp_in)
                real = acc.astype(np.float64) * (wrec["s_w"] * s_in)
                if n.relu:
                    real = np.maximum(real, 0.0)
                los.append(real.min())
                his.append(real.max())
            out_q[i] = quant.choose_qparams(min(los), max(his))
        elif n.op in ("maxpool", "gap", "shuffle"):
            out_q[i] = out_q[n.inputs[0]]
        # input already set
    return out_q


def mac_accumulator(n, out_shape, wrec, x, zp_in) -> np.ndarray:
    """Exact accumulator of one conv/dense node (grouped), [cout, cols]."""
    from compile.model import im2col

    if n.op == "dense":
        return approx_gemm("exact", 0, False, wrec["w_q"], x.reshape(-1, 1),
                           wrec["zp_w"], zp_in, wrec["b_q"])
    h, w, cin = x.shape
    oh, ow, cout = out_shape
    g = n.groups
    cpg_in, cpg_out = cin // g, cout // g
    acc = np.empty((cout, oh * ow), np.int64)
    for gi in range(g):
        xg = x[..., gi * cpg_in:(gi + 1) * cpg_in]
        a_cols = im2col(xg, n.k, n.stride, n.pad, zp_in)
        acc[gi * cpg_out:(gi + 1) * cpg_out] = approx_gemm(
            "exact", 0, False,
            wrec["w_q"][gi * cpg_out:(gi + 1) * cpg_out], a_cols,
            wrec["zp_w"], zp_in,
            wrec["b_q"][gi * cpg_out:(gi + 1) * cpg_out])
    return acc


def forward_until(nodes, shapes, weights, out_q, img, stop) -> list:
    """Quantized forward of nodes[0..stop) via QuantModel (exact path)."""
    qm = QuantModel(MODEL_NAME, nodes[:stop], shapes[:stop],
                    out_q[:stop], weights)
    outs = []
    for i, n in enumerate(qm.nodes):
        if n.op == "input":
            y = img
        elif n.op in ("conv", "dense"):
            y = qm._mac_layer(i, n, outs, "exact", 0, False)
        else:
            # reuse the full-forward op implementations by running forward
            # on the truncated model is wasteful; replicate passthroughs
            if n.op == "maxpool":
                x = outs[n.inputs[0]]
                h, w, c = x.shape
                y = x[:h // 2 * 2, :w // 2 * 2].reshape(h // 2, 2, w // 2, 2, c)
                y = y.max(axis=(1, 3))
            elif n.op == "gap":
                x = outs[n.inputs[0]].astype(np.int64)
                npix = x.shape[0] * x.shape[1]
                y = ((x.sum(axis=(0, 1)) * 2 + npix) // (2 * npix)).astype(np.uint8)
                y = y.reshape(1, 1, -1)
            elif n.op == "shuffle":
                x = outs[n.inputs[0]]
                h, w, c = x.shape
                gg = n.groups
                y = x.reshape(h, w, gg, c // gg).transpose(0, 1, 3, 2).reshape(h, w, c)
            else:
                raise ValueError(n.op)
        outs.append(y)
    return outs


def argmax_last(logits: np.ndarray) -> int:
    """Last-max tie rule — mirrors the rust coordinator's argmax."""
    return int(len(logits) - 1 - np.argmax(logits[::-1]))


GRID = [("perforated", m) for m in (1, 2, 3)] + \
       [("recursive", m) for m in (2, 3, 4)] + \
       [("truncated", m) for m in (5, 6, 7)]


def evaluate(qm, imgs, labels, family, m, use_cv, ms=None) -> float:
    """Top-1 accuracy; ms (per-layer m) mirrors rust ForwardOpts::layerwise
    by running the forward with a per-MAC-layer level."""
    correct = 0
    for img, label in zip(imgs, labels):
        logits = forward_policy(qm, img, family, use_cv, ms) if ms is not None \
            else qm.forward(img, family, m, use_cv)
        correct += argmax_last(logits) == label
    return correct / len(imgs)


def forward_policy(qm, img, family, use_cv, ms) -> np.ndarray:
    """Per-layer-m forward (m = 0 -> exact layer), mirror of the rust
    layerwise path: identical per-layer arithmetic, level chosen per MAC
    layer ordinal."""
    outs = []
    mac_idx = 0
    for i, n in enumerate(qm.nodes):
        if n.op == "input":
            y = img
        elif n.op in ("conv", "dense"):
            m_eff = ms[mac_idx]
            mac_idx += 1
            fam = family if m_eff > 0 else "exact"
            y = qm._mac_layer(i, n, outs, fam, m_eff, use_cv if m_eff > 0 else False)
        elif n.op == "maxpool":
            x = outs[n.inputs[0]]
            h, w, c = x.shape
            y = x[:h // 2 * 2, :w // 2 * 2].reshape(h // 2, 2, w // 2, 2, c)
            y = y.max(axis=(1, 3))
        elif n.op == "gap":
            x = outs[n.inputs[0]].astype(np.int64)
            npix = x.shape[0] * x.shape[1]
            y = ((x.sum(axis=(0, 1)) * 2 + npix) // (2 * npix)).astype(np.uint8)
            y = y.reshape(1, 1, -1)
        elif n.op == "shuffle":
            x = outs[n.inputs[0]]
            h, w, c = x.shape
            g = n.groups
            y = x.reshape(h, w, g, c // g).transpose(0, 1, 3, 2).reshape(h, w, c)
        else:
            raise ValueError(n.op)
        outs.append(y)
    s, zp = qm.out_q[len(qm.nodes) - 1]
    return (outs[-1].reshape(-1).astype(np.float64) - zp) * s


def greedy_sim(qm, imgs, labels, family, m_hi, budget_pct):
    """Mirror of rust report::layerwise::{sensitivity, greedy_policy}."""
    n_layers = sum(1 for n in qm.nodes if n.op in ("conv", "dense"))
    sens = []
    for layer in range(n_layers):
        ms = [0] * n_layers
        ms[layer] = m_hi
        sens.append(evaluate(qm, imgs, labels, family, m_hi, True, ms=ms))
    exact_acc = evaluate(qm, imgs, labels, "exact", 0, False,
                         ms=[0] * n_layers)
    floor = exact_acc - budget_pct / 100.0
    order = sorted(range(n_layers), key=lambda i: -sens[i])  # stable desc
    ms = [0] * n_layers
    acc = exact_acc
    for layer in order:
        ms[layer] = m_hi
        trial = evaluate(qm, imgs, labels, family, m_hi, True, ms=ms)
        if trial >= floor:
            acc = trial
        else:
            ms[layer] = 0
    return ms, acc, exact_acc, sens


def main() -> None:
    rng = np.random.default_rng(SEED)
    nodes = build_nodes()
    shapes = infer_shapes(nodes, IN_SHAPE)
    weights = synth_weights(nodes, shapes, rng)
    imgs = rng.integers(0, 256, size=(N_IMAGES,) + IN_SHAPE).astype(np.uint8)

    out_q = calibrate(nodes, shapes, weights, imgs[:N_CALIB])
    qm = QuantModel(MODEL_NAME, nodes, shapes, out_q, weights)

    # Labels = exact argmax (last-max rule): the exact design scores 100%.
    labels = np.array(
        [argmax_last(qm.forward(img, "exact", 0, False)) for img in imgs],
        np.uint16)

    for sub in ("models", "data", "golden"):
        (OUT / sub).mkdir(parents=True, exist_ok=True)
    export.write_model(OUT / f"models/{MODEL_NAME}.cvm", qm, 10)
    export.write_dataset(OUT / "data/hsynth_test.cvd", imgs, labels,
                         quant.INPUT_SCALE, 0)

    # Golden vectors: exact on two images + the full paper grid x {V, raw}
    # on two images each = 2 + 9*2*2 = 38 vectors.
    n_gv = 0
    for img_index in (0, 1):
        logits = qm.forward(imgs[img_index], "exact", 0, False)
        export.write_golden(OUT / f"golden/{MODEL_NAME}_e0_n_{img_index}.gv",
                            MODEL_NAME, "exact", 0, False, img_index, logits)
        n_gv += 1
    for family, m in GRID:
        for use_cv in (True, False):
            for img_index in (0, 1):
                logits = qm.forward(imgs[img_index], family, m, use_cv)
                tag = f"{family[0]}{m}_{'v' if use_cv else 'n'}_{img_index}"
                export.write_golden(OUT / f"golden/{MODEL_NAME}_{tag}.gv",
                                    MODEL_NAME, family, m, use_cv, img_index,
                                    logits)
                n_gv += 1

    # ---- verification summary (drives the policy bench tuning) ----------
    print(f"wrote {OUT} ({n_gv} golden vectors, {N_IMAGES} images)")
    print("node out_q:", [(round(s, 6), z) for s, z in out_q])
    exact_acc = evaluate(qm, imgs, labels, "exact", 0, False,
                         ms=[0] * 4)
    print(f"exact accuracy: {exact_acc:.4f}")
    for family, m in GRID:
        acc_v = evaluate(qm, imgs, labels, family, m, True)
        acc_r = evaluate(qm, imgs, labels, family, m, False)
        print(f"  uniform {family:<10} m={m}: +V {acc_v:.4f}  raw {acc_r:.4f}")
    for family, m_hi, budget in (("perforated", 3, 0.8), ("truncated", 7, 0.8)):
        ms, acc, exact, sens = greedy_sim(qm, imgs, labels, family, m_hi, budget)
        print(f"greedy {family} m_hi={m_hi} budget={budget}%: ms={ms} "
              f"acc={acc:.4f} exact={exact:.4f} sens={[round(s, 3) for s in sens]}")


if __name__ == "__main__":
    main()
