#!/usr/bin/env python3
"""Generate the checked-in hermetic mini-artifacts under rust/tests/hermetic/.

Purpose: rust/tests/golden.rs must execute for real in CI — not print
"skipping" — without `make artifacts` (slow, jax training) or network access.
This script builds a small deterministic synthetic model + dataset and runs
the repo's *python reference* quantized forward (compile/model.py — the
implementation the rust engine mirrors bit-for-bit) to produce golden
vectors for every (family, m, use_cv) point of the paper grid:

  rust/tests/hermetic/models/hermnet_hsynth.cvm
  rust/tests/hermetic/data/hsynth_test.cvd        (64 images, 10 classes)
  rust/tests/hermetic/golden/*.gv                 (38 vectors)
  rust/tests/hermetic/golden_paired/*.json        (paired/polarity vectors)

The golden_paired tier mirrors the rust positive/negative pairing axis:
positive-polarity (round-up) multiplier variants and per-layer even/odd
pairings, serialized as JSON (policy document + full-precision logits)
because the .gv format encodes only a uniform (family, m, cv) triple.

Everything is seeded and integer/float64-deterministic, so regenerating
produces byte-identical files. Labels are the exact-forward argmax (last-max
tie rule, matching the rust coordinator's argmax), so the exact design
scores 100% on the hermetic set and approximate designs measure a real,
deterministic accuracy loss — which is what benches/policy_serving.rs and
the layerwise tests evaluate against.

Run from the repo root:  python3 scripts/gen_hermetic_golden.py
(needs numpy; imports the repo's python/compile package)
"""

from __future__ import annotations

import json
import pathlib
import sys

import numpy as np

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "python"))

from compile import export, quant  # noqa: E402
from compile.model import QuantModel, approx_gemm, infer_shapes, np_err_acc  # noqa: E402
from compile.nets import Node  # noqa: E402

OUT = REPO / "rust/tests/hermetic"
MODEL_NAME = "hermnet_hsynth"  # dataset stem parses to "hsynth"
N_IMAGES = 64
N_CALIB = 32
IN_SHAPE = (8, 8, 3)
# Seed chosen (swept 0..15) so the hermetic set differentiates designs:
# exact scores 1.0, every uniform (family, m) grid point loses accuracy
# (0.94 .. 0.55), and the greedy layerwise search finds a mixed policy
# (conv1 at m=3, ~40% of MACs, rest exact) with zero loss — i.e. a mixed
# policy that dominates the whole uniform grid. The rust layerwise tests
# and benches/policy_serving.rs assert exactly this structure.
SEED = 3
# Per-class spread of the dense rows: rows share one concentrated base row
# (common-mode approximation error cancels in argmax) plus a small delta
# that sets the logit margins the approximation noise competes with.
DENSE_DELTA_SIGMA = 5.0


def build_nodes() -> list[Node]:
    """input(8,8,3) -> conv3x3(8) -> conv3x3 g2 (8) -> shuffle(2) ->
    maxpool -> conv1x1(16) -> gap -> dense(10)."""
    return [
        Node("input"),
        Node("conv", [0], cout=8, k=3, stride=1, pad=1, groups=1, relu=True),
        Node("conv", [1], cout=8, k=3, stride=1, pad=1, groups=2, relu=True),
        Node("shuffle", [2], groups=2),
        Node("maxpool", [3], k=2, stride=2),
        Node("conv", [4], cout=16, k=1, stride=1, pad=0, groups=1, relu=True),
        Node("gap", [5]),
        Node("dense", [6], nout=10, relu=False),
    ]


def synth_weights(nodes, shapes, rng) -> dict:
    """Trained-net-like uint8 weights: concentrated around the zero point
    (paper Fig. 4) so C = E[W] is an effective control variate. Dense rows
    share a base row plus a small per-class delta (see DENSE_DELTA_SIGMA)."""
    weights = {}
    for i, n in enumerate(nodes):
        if n.op == "conv":
            cin = shapes[n.inputs[0]][2] // n.groups
            kdim = n.k * n.k * cin
            cout = n.cout
            w = rng.normal(128.0, 22.0, size=(cout, kdim))
        elif n.op == "dense":
            kdim = int(np.prod(shapes[n.inputs[0]]))
            cout = n.nout
            base = rng.normal(128.0, 22.0, size=(1, kdim))
            w = base + rng.normal(0.0, DENSE_DELTA_SIGMA, size=(cout, kdim))
        else:
            continue
        w_q = np.clip(np.rint(w), 0, 255).astype(np.uint8)
        b_q = rng.integers(-400, 401, size=cout).astype(np.int32)
        weights[i] = {
            "w_q": w_q,
            "b_q": b_q,
            "s_w": float(np.float32(0.01)),
            "zp_w": 128,
        }
    return weights


def calibrate(nodes, shapes, weights, calib_imgs) -> list[tuple[float, int]]:
    """Sequentially choose per-node (scale, zp): MAC layers from the min/max
    of their exact-accumulator real values over the calib batch (post-ReLU
    observation, like the float calibrator); passthrough ops (maxpool, gap,
    shuffle) keep their input's quantization domain."""
    out_q: list[tuple[float, int]] = [(quant.INPUT_SCALE, 0)] * len(nodes)
    # Per-image forward, filling out_q before each node is first consumed.
    for i, n in enumerate(nodes):
        if n.op in ("conv", "dense"):
            wrec = weights[i]
            s_in, zp_in = out_q[n.inputs[0]]
            los, his = [], []
            for img in calib_imgs:
                outs = forward_until(nodes, shapes, weights, out_q, img, i)
                x = outs[n.inputs[0]]
                acc = mac_accumulator(n, shapes[i], wrec, x, zp_in)
                real = acc.astype(np.float64) * (wrec["s_w"] * s_in)
                if n.relu:
                    real = np.maximum(real, 0.0)
                los.append(real.min())
                his.append(real.max())
            out_q[i] = quant.choose_qparams(min(los), max(his))
        elif n.op in ("maxpool", "gap", "shuffle"):
            out_q[i] = out_q[n.inputs[0]]
        # input already set
    return out_q


def mac_accumulator(n, out_shape, wrec, x, zp_in) -> np.ndarray:
    """Exact accumulator of one conv/dense node (grouped), [cout, cols]."""
    from compile.model import im2col

    if n.op == "dense":
        return approx_gemm("exact", 0, False, wrec["w_q"], x.reshape(-1, 1),
                           wrec["zp_w"], zp_in, wrec["b_q"])
    h, w, cin = x.shape
    oh, ow, cout = out_shape
    g = n.groups
    cpg_in, cpg_out = cin // g, cout // g
    acc = np.empty((cout, oh * ow), np.int64)
    for gi in range(g):
        xg = x[..., gi * cpg_in:(gi + 1) * cpg_in]
        a_cols = im2col(xg, n.k, n.stride, n.pad, zp_in)
        acc[gi * cpg_out:(gi + 1) * cpg_out] = approx_gemm(
            "exact", 0, False,
            wrec["w_q"][gi * cpg_out:(gi + 1) * cpg_out], a_cols,
            wrec["zp_w"], zp_in,
            wrec["b_q"][gi * cpg_out:(gi + 1) * cpg_out])
    return acc


def forward_until(nodes, shapes, weights, out_q, img, stop) -> list:
    """Quantized forward of nodes[0..stop) via QuantModel (exact path)."""
    qm = QuantModel(MODEL_NAME, nodes[:stop], shapes[:stop],
                    out_q[:stop], weights)
    outs = []
    for i, n in enumerate(qm.nodes):
        if n.op == "input":
            y = img
        elif n.op in ("conv", "dense"):
            y = qm._mac_layer(i, n, outs, "exact", 0, False)
        else:
            # reuse the full-forward op implementations by running forward
            # on the truncated model is wasteful; replicate passthroughs
            if n.op == "maxpool":
                x = outs[n.inputs[0]]
                h, w, c = x.shape
                y = x[:h // 2 * 2, :w // 2 * 2].reshape(h // 2, 2, w // 2, 2, c)
                y = y.max(axis=(1, 3))
            elif n.op == "gap":
                x = outs[n.inputs[0]].astype(np.int64)
                npix = x.shape[0] * x.shape[1]
                y = ((x.sum(axis=(0, 1)) * 2 + npix) // (2 * npix)).astype(np.uint8)
                y = y.reshape(1, 1, -1)
            elif n.op == "shuffle":
                x = outs[n.inputs[0]]
                h, w, c = x.shape
                gg = n.groups
                y = x.reshape(h, w, gg, c // gg).transpose(0, 1, 3, 2).reshape(h, w, c)
            else:
                raise ValueError(n.op)
        outs.append(y)
    return outs


def argmax_last(logits: np.ndarray) -> int:
    """Last-max tie rule — mirrors the rust coordinator's argmax."""
    return int(len(logits) - 1 - np.argmax(logits[::-1]))


# ---------------------------------------------------------------------------
# Positive/negative polarity + paired-layer mirror (rust approx::Polarity,
# nn::policy::{LayerPoint, PairedPoint}, nn::gemm::paired_gemm_planned)
# ---------------------------------------------------------------------------


def np_comp(x: np.ndarray, m: int) -> np.ndarray:
    """Modular complement of the m low bits (rust approx::comp_low)."""
    mask = (1 << m) - 1
    return ((1 << m) - (x & mask)) & mask


def np_err_acc_pol(family: str, pol: str, w: np.ndarray, a: np.ndarray,
                   m: int) -> np.ndarray:
    """Signed sum_k eps(W,A) = exact − AM (i64): ≥0 for neg, ≤0 for pos."""
    w = w.astype(np.int64)
    a = a.astype(np.int64)
    if family == "exact" or m == 0:
        return np.zeros((w.shape[0], a.shape[1]), np.int64)
    if pol == "neg":
        return np_err_acc(family, w, a, m)
    if family == "perforated":
        return -(w @ np_comp(a, m))
    if family == "recursive":
        return -(np_comp(w, m) @ np_comp(a, m))
    if family == "truncated":
        acc = np.zeros((w.shape[0], a.shape[1]), np.int64)
        for i in range(m):
            acc += (np_comp(w, m - i) @ ((a >> i) & 1)) << i
        return -acc
    raise ValueError(family)


def np_x_pol(family: str, pol: str, a: np.ndarray, m: int) -> np.ndarray:
    """Per-element CV regressor x (rust approx::xvar_pol)."""
    a = a.astype(np.int64)
    low = a & ((1 << m) - 1)
    if family == "truncated":
        return (low != 0).astype(np.int64)
    if pol == "neg":
        return low
    return np_comp(a, m)


def div_round(num: np.ndarray, den: int) -> np.ndarray:
    """Round-half-away-from-zero division (rust cv::div_round)."""
    num = num.astype(np.int64)
    return np.where(num >= 0, (num + den // 2) // den,
                    -((-num + den // 2) // den))


def cv_constants_pol(family: str, pol: str, w: np.ndarray, m: int,
                     k_valid: int):
    """Per-row (C, C0) in Q.4 (rust cv::constants_pol). `w` may be a
    parity-masked panel; `k_valid` divides the averages."""
    w = w.astype(np.int64)
    rows = w.shape[0]
    if family == "exact" or m == 0 or k_valid == 0:
        z = np.zeros(rows, np.int64)
        return z, z
    if family == "perforated":
        num = w.sum(axis=1)
    elif family == "recursive":
        part = np_comp(w, m) if pol == "pos" else (w & ((1 << m) - 1))
        num = part.sum(axis=1)
    elif family == "truncated":
        num = np.zeros(rows, np.int64)
        for i in range(m):
            b = m - i
            part = np_comp(w, b) if pol == "pos" else (w & ((1 << b) - 1))
            num += part.sum(axis=1) << i
    else:
        raise ValueError(family)
    den = k_valid * (2 if family == "truncated" else 1)
    c = div_round(num * 16, den)
    c0 = div_round(num * 16, 1 << (m + 1)) if family == "truncated" \
        else np.zeros(rows, np.int64)
    if pol == "pos":
        c, c0 = -c, -c0
    return c, c0


def point(family: str, m: int, pol: str = "neg", use_cv: bool = True) -> dict:
    return {"family": family, "m": m, "polarity": pol, "use_cv": use_cv}


EXACT_POINT = point("exact", 0, "neg", False)


def norm_point(pt: dict) -> dict:
    """rust LayerPoint::normalized: m == 0 or exact family -> EXACT."""
    if pt["family"] == "exact" or pt["m"] == 0:
        return dict(EXACT_POINT)
    return pt


def paired(even: dict, odd: dict) -> dict:
    return {"paired": {"even": even, "odd": odd}}


def assignment_gemm(assign: dict, w_q: np.ndarray, a_q: np.ndarray,
                    zp_w: int, zp_a: int, bias_q: np.ndarray) -> np.ndarray:
    """One layer GEMM under a point or paired assignment (rust
    approx_gemm_planned / paired_gemm_planned, i64 accumulators)."""
    wi = w_q.astype(np.int64)
    ai = a_q.astype(np.int64)
    k = wi.shape[1]
    if "paired" not in assign:
        pt = norm_point(assign)
        fam, m, pol, use_cv = (pt["family"], pt["m"], pt["polarity"],
                               pt["use_cv"])
        acc = wi @ ai - np_err_acc_pol(fam, pol, wi, ai, m)
        if use_cv and fam != "exact" and m > 0:
            c, c0 = cv_constants_pol(fam, pol, wi, m, k)
            sumx = np_x_pol(fam, pol, ai, m).sum(axis=0)
            acc = acc + ((c[:, None] * sumx[None, :] + c0[:, None] + 8) >> 4)
    else:
        halves = (norm_point(assign["paired"]["even"]),
                  norm_point(assign["paired"]["odd"]))
        acc = wi @ ai
        kk = np.arange(k)
        for parity, pt in enumerate(halves):
            fam, m, pol = pt["family"], pt["m"], pt["polarity"]
            if fam == "exact" or m == 0:
                continue
            wp = wi.copy()
            wp[:, (kk % 2) != parity] = 0
            acc = acc - np_err_acc_pol(fam, pol, wp, ai, m)
        for parity, pt in enumerate(halves):
            fam, m, pol, use_cv = (pt["family"], pt["m"], pt["polarity"],
                                   pt["use_cv"])
            if not use_cv or fam == "exact" or m == 0:
                continue
            k_valid = (k + 1) // 2 if parity == 0 else k // 2
            wp = wi.copy()
            wp[:, (kk % 2) != parity] = 0
            c, c0 = cv_constants_pol(fam, pol, wp, m, k_valid)
            x = np_x_pol(fam, pol, ai, m)
            sumx = x[(kk % 2) == parity].sum(axis=0)
            acc = acc + ((c[:, None] * sumx[None, :] + c0[:, None] + 8) >> 4)
    sum_a = ai.sum(axis=0)
    sum_w = wi.sum(axis=1)
    return (acc - zp_w * sum_a[None, :] - zp_a * sum_w[:, None]
            + k * zp_w * zp_a + bias_q.astype(np.int64)[:, None])


def forward_assignments(qm, img, assignments) -> np.ndarray:
    """Quantized forward with one assignment per MAC layer (rust
    ForwardOpts::with_policy over a possibly-paired LayerPolicy)."""
    outs = []
    mac_idx = 0
    for i, n in enumerate(qm.nodes):
        s_out, zp_out = qm.out_q[i]
        if n.op == "input":
            y = img
        elif n.op in ("conv", "dense"):
            assign = assignments[mac_idx]
            mac_idx += 1
            wrec = qm.weights[i]
            x = outs[n.inputs[0]]
            s_in, zp_in = qm.out_q[n.inputs[0]]
            mult = wrec["s_w"] * s_in / s_out
            zp_w = wrec["zp_w"]
            if n.op == "dense":
                acc = assignment_gemm(assign, wrec["w_q"], x.reshape(-1, 1),
                                      zp_w, zp_in, wrec["b_q"])
                q = quant.requantize(acc, mult, zp_out).reshape(-1)
                if n.relu:
                    q = np.maximum(q, zp_out)
                y = q.reshape(1, 1, -1)
            else:
                from compile.model import im2col
                h, w, cin = x.shape
                oh, ow, cout = qm.shapes[i]
                g = n.groups
                y2 = np.empty((cout, oh * ow), np.uint8)
                cpg_in, cpg_out = cin // g, cout // g
                for gi in range(g):
                    xg = x[..., gi * cpg_in:(gi + 1) * cpg_in]
                    a_cols = im2col(xg, n.k, n.stride, n.pad, zp_in)
                    wq = wrec["w_q"][gi * cpg_out:(gi + 1) * cpg_out]
                    bq = wrec["b_q"][gi * cpg_out:(gi + 1) * cpg_out]
                    acc = assignment_gemm(assign, wq, a_cols, zp_w, zp_in, bq)
                    q = quant.requantize(acc, mult, zp_out)
                    if n.relu:
                        q = np.maximum(q, zp_out)
                    y2[gi * cpg_out:(gi + 1) * cpg_out] = q
                y = y2.T.reshape(oh, ow, cout)
        elif n.op == "maxpool":
            x = outs[n.inputs[0]]
            h, w, c = x.shape
            y = x[:h // 2 * 2, :w // 2 * 2].reshape(h // 2, 2, w // 2, 2, c)
            y = y.max(axis=(1, 3))
        elif n.op == "gap":
            x = outs[n.inputs[0]].astype(np.int64)
            npix = x.shape[0] * x.shape[1]
            y = ((x.sum(axis=(0, 1)) * 2 + npix) // (2 * npix)).astype(np.uint8)
            y = y.reshape(1, 1, -1)
        elif n.op == "shuffle":
            x = outs[n.inputs[0]]
            h, w, c = x.shape
            gg = n.groups
            y = x.reshape(h, w, gg, c // gg).transpose(0, 1, 3, 2).reshape(h, w, c)
        else:
            raise ValueError(n.op)
        outs.append(y)
    s, zp = qm.out_q[len(qm.nodes) - 1]
    return (outs[-1].reshape(-1).astype(np.float64) - zp) * s


def mirrored(family: str, m: int, use_cv: bool = True) -> dict:
    """The canonical cancelling pair (rust PairedPoint::mirrored)."""
    return paired(point(family, m, "neg", use_cv), point(family, m, "pos", use_cv))


def evaluate_assignments(qm, imgs, labels, assignments) -> float:
    correct = 0
    for img, label in zip(imgs, labels):
        correct += argmax_last(forward_assignments(qm, img, assignments)) == label
    return correct / len(imgs)


GRID = [("perforated", m) for m in (1, 2, 3)] + \
       [("recursive", m) for m in (2, 3, 4)] + \
       [("truncated", m) for m in (5, 6, 7)]


def evaluate(qm, imgs, labels, family, m, use_cv, ms=None) -> float:
    """Top-1 accuracy; ms (per-layer m) mirrors rust ForwardOpts::layerwise
    by running the forward with a per-MAC-layer level."""
    correct = 0
    for img, label in zip(imgs, labels):
        logits = forward_policy(qm, img, family, use_cv, ms) if ms is not None \
            else qm.forward(img, family, m, use_cv)
        correct += argmax_last(logits) == label
    return correct / len(imgs)


def forward_policy(qm, img, family, use_cv, ms) -> np.ndarray:
    """Per-layer-m forward (m = 0 -> exact layer), mirror of the rust
    layerwise path: identical per-layer arithmetic, level chosen per MAC
    layer ordinal."""
    outs = []
    mac_idx = 0
    for i, n in enumerate(qm.nodes):
        if n.op == "input":
            y = img
        elif n.op in ("conv", "dense"):
            m_eff = ms[mac_idx]
            mac_idx += 1
            fam = family if m_eff > 0 else "exact"
            y = qm._mac_layer(i, n, outs, fam, m_eff, use_cv if m_eff > 0 else False)
        elif n.op == "maxpool":
            x = outs[n.inputs[0]]
            h, w, c = x.shape
            y = x[:h // 2 * 2, :w // 2 * 2].reshape(h // 2, 2, w // 2, 2, c)
            y = y.max(axis=(1, 3))
        elif n.op == "gap":
            x = outs[n.inputs[0]].astype(np.int64)
            npix = x.shape[0] * x.shape[1]
            y = ((x.sum(axis=(0, 1)) * 2 + npix) // (2 * npix)).astype(np.uint8)
            y = y.reshape(1, 1, -1)
        elif n.op == "shuffle":
            x = outs[n.inputs[0]]
            h, w, c = x.shape
            g = n.groups
            y = x.reshape(h, w, g, c // g).transpose(0, 1, 3, 2).reshape(h, w, c)
        else:
            raise ValueError(n.op)
        outs.append(y)
    s, zp = qm.out_q[len(qm.nodes) - 1]
    return (outs[-1].reshape(-1).astype(np.float64) - zp) * s


def greedy_sim(qm, imgs, labels, family, m_hi, budget_pct):
    """Mirror of rust report::layerwise::{sensitivity, greedy_policy}."""
    n_layers = sum(1 for n in qm.nodes if n.op in ("conv", "dense"))
    sens = []
    for layer in range(n_layers):
        ms = [0] * n_layers
        ms[layer] = m_hi
        sens.append(evaluate(qm, imgs, labels, family, m_hi, True, ms=ms))
    exact_acc = evaluate(qm, imgs, labels, "exact", 0, False,
                         ms=[0] * n_layers)
    floor = exact_acc - budget_pct / 100.0
    order = sorted(range(n_layers), key=lambda i: -sens[i])  # stable desc
    ms = [0] * n_layers
    acc = exact_acc
    for layer in order:
        ms[layer] = m_hi
        trial = evaluate(qm, imgs, labels, family, m_hi, True, ms=ms)
        if trial >= floor:
            acc = trial
        else:
            ms[layer] = 0
    return ms, acc, exact_acc, sens


def main() -> None:
    rng = np.random.default_rng(SEED)
    nodes = build_nodes()
    shapes = infer_shapes(nodes, IN_SHAPE)
    weights = synth_weights(nodes, shapes, rng)
    imgs = rng.integers(0, 256, size=(N_IMAGES,) + IN_SHAPE).astype(np.uint8)

    out_q = calibrate(nodes, shapes, weights, imgs[:N_CALIB])
    qm = QuantModel(MODEL_NAME, nodes, shapes, out_q, weights)

    # Labels = exact argmax (last-max rule): the exact design scores 100%.
    labels = np.array(
        [argmax_last(qm.forward(img, "exact", 0, False)) for img in imgs],
        np.uint16)

    for sub in ("models", "data", "golden", "golden_paired"):
        (OUT / sub).mkdir(parents=True, exist_ok=True)
    export.write_model(OUT / f"models/{MODEL_NAME}.cvm", qm, 10)
    export.write_dataset(OUT / "data/hsynth_test.cvd", imgs, labels,
                         quant.INPUT_SCALE, 0)

    # Golden vectors: exact on two images + the full paper grid x {V, raw}
    # on two images each = 2 + 9*2*2 = 38 vectors.
    n_gv = 0
    for img_index in (0, 1):
        logits = qm.forward(imgs[img_index], "exact", 0, False)
        export.write_golden(OUT / f"golden/{MODEL_NAME}_e0_n_{img_index}.gv",
                            MODEL_NAME, "exact", 0, False, img_index, logits)
        n_gv += 1
    for family, m in GRID:
        for use_cv in (True, False):
            for img_index in (0, 1):
                logits = qm.forward(imgs[img_index], family, m, use_cv)
                tag = f"{family[0]}{m}_{'v' if use_cv else 'n'}_{img_index}"
                export.write_golden(OUT / f"golden/{MODEL_NAME}_{tag}.gv",
                                    MODEL_NAME, family, m, use_cv, img_index,
                                    logits)
                n_gv += 1

    # Paired/polarity golden vectors: JSON sidecars (policy document +
    # full-precision logits) for the rust golden_paired tier. Fixed set of
    # five policies exercising mirrored pairings, cross-point pairings,
    # uniform positive polarity and half-exact pairings, on two images each.
    paired_policies = [
        ("pp_perf2_mirror", [mirrored("perforated", 2)] * 4),
        ("pp_trunc6_mirror", [mirrored("truncated", 6)] * 4),
        ("pp_mixed", [
            mirrored("perforated", 3),
            dict(EXACT_POINT),
            point("recursive", 3, "pos", False),
            paired(point("truncated", 6, "neg", False),
                   point("truncated", 5, "pos", True)),
        ]),
        ("pp_perf2_pos_uniform", [point("perforated", 2, "pos", True)] * 4),
        ("pp_half_exact", [paired(dict(EXACT_POINT),
                                  point("perforated", 2, "pos", True))] * 4),
    ]
    n_pp = 0
    for name, assignments in paired_policies:
        for img_index in (0, 1):
            logits = forward_assignments(qm, imgs[img_index], assignments)
            doc = {
                "model": MODEL_NAME,
                "img_index": img_index,
                "policy": {"n_layers": len(assignments),
                           "layers": assignments},
                "logits": [float(v) for v in logits],
            }
            path = OUT / f"golden_paired/{name}_{img_index}.json"
            path.write_text(json.dumps(doc, indent=1) + "\n")
            n_pp += 1

    # ---- verification summary (drives the policy bench tuning) ----------
    print(f"wrote {OUT} ({n_gv} golden vectors, {n_pp} paired vectors, "
          f"{N_IMAGES} images)")
    print("node out_q:", [(round(s, 6), z) for s, z in out_q])
    exact_acc = evaluate(qm, imgs, labels, "exact", 0, False,
                         ms=[0] * 4)
    print(f"exact accuracy: {exact_acc:.4f}")
    for family, m in GRID:
        acc_v = evaluate(qm, imgs, labels, family, m, True)
        acc_r = evaluate(qm, imgs, labels, family, m, False)
        print(f"  uniform {family:<10} m={m}: +V {acc_v:.4f}  raw {acc_r:.4f}")
    for family, m_hi, budget in (("perforated", 3, 0.8), ("truncated", 7, 0.8)):
        ms, acc, exact, sens = greedy_sim(qm, imgs, labels, family, m_hi, budget)
        print(f"greedy {family} m_hi={m_hi} budget={budget}%: ms={ms} "
              f"acc={acc:.4f} exact={exact:.4f} sens={[round(s, 3) for s in sens]}")
    # Paired-space reference numbers (pin the rust layerwise/bench claims).
    for family, m in GRID:
        acc_pair = evaluate_assignments(qm, imgs, labels, [mirrored(family, m)] * 4)
        acc_pos = evaluate_assignments(
            qm, imgs, labels, [point(family, m, "pos", True)] * 4)
        print(f"  paired  {family:<10} m={m}: mirror {acc_pair:.4f}  "
              f"uniform-pos {acc_pos:.4f}")
    # Mirror of rust greedy_paired_policy seeded from the perforated m=3
    # mixed result: per layer (most tolerant first) descend the m ladder of
    # mirrored pairings, keeping the first rung whose measured accuracy
    # stays at or above the mixed policy's. The power guard (a pairing may
    # not cost more than what the layer runs today) means exact layers
    # accept any m while the already-approximate layer only accepts the
    # power-neutral m_hi mirror.
    family, m_hi = "perforated", 3
    ms, base_acc, exact_acc2, sens = greedy_sim(qm, imgs, labels, family, m_hi, 0.8)
    assigns = [point(family, m, "neg", True) if m > 0 else dict(EXACT_POINT)
               for m in ms]
    order = sorted(range(len(sens)), key=lambda i: -sens[i])
    acc = base_acc
    upgraded = []
    for layer in order:
        was_exact = assigns[layer] == EXACT_POINT
        for m in range(m_hi, 0, -1):
            if not was_exact and m != m_hi:
                continue  # power guard: cheaper rungs only for exact layers
            prev = assigns[layer]
            assigns[layer] = mirrored(family, m)
            trial = evaluate_assignments(qm, imgs, labels, assigns)
            if trial >= base_acc:
                acc = trial
                upgraded.append((layer, m))
                break
            assigns[layer] = prev
    print(f"greedy paired {family} m_hi={m_hi}: upgraded (layer, m) {upgraded} "
          f"acc={acc:.4f} (mixed {base_acc:.4f}, exact {exact_acc2:.4f})")


if __name__ == "__main__":
    main()
