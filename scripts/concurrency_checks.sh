#!/usr/bin/env bash
# Optional deep concurrency verification: cargo miri (UB/aliasing on the
# unit-scoped util/approx/cv suites) and ThreadSanitizer (data races on
# the coordinator tests). Both need nightly toolchain components, so each
# stage skips-with-warning when its component is absent — mirroring the
# clippy gate pattern in verify.sh. CI runs this in a separate
# continue-on-error job; locally: CVAPPROX_CONCURRENCY_CHECKS=1
# scripts/verify.sh, or invoke this script directly.

set -euo pipefail
cd "$(dirname "$0")/.."

status=0

# --- miri: unit-scoped interpreter run (no threads, no file I/O paths) --
# Scope: the pure-computation modules whose invariants the rest leans on.
if cargo +nightly miri --version >/dev/null 2>&1; then
    echo "== miri: util + approx + cv unit tests =="
    # MIRIFLAGS: isolation off so env-var reads (CVAPPROX_THREADS) work.
    MIRIFLAGS="-Zmiri-disable-isolation" \
        cargo +nightly miri test -p cvapprox --lib util approx cv || status=1
else
    echo "warning: cargo miri not installed (rustup +nightly component add miri); skipping" >&2
fi

# --- ThreadSanitizer: coordinator pool under real threads ---------------
# Needs -Zsanitizer (nightly) and the matching std; skip when absent.
if cargo +nightly --version >/dev/null 2>&1 \
    && rustup component list --toolchain nightly 2>/dev/null | grep -q "rust-src (installed)"; then
    echo "== tsan: coordinator tests =="
    RUSTFLAGS="-Zsanitizer=thread" RUSTDOCFLAGS="-Zsanitizer=thread" \
        cargo +nightly test -Zbuild-std --target "$(rustc -vV | sed -n 's/host: //p')" \
        -p cvapprox --lib coordinator || status=1
else
    echo "warning: nightly rust-src not installed (rustup +nightly component add rust-src); skipping tsan" >&2
fi

if [ "$status" != "0" ]; then
    echo "concurrency checks FAILED" >&2
fi
exit "$status"
