#!/usr/bin/env python3
"""Independent transliteration of the rust/tests/schedules.rs models.

The Rust harness asserts *exact* exhaustive schedule counts (violations
never truncate a schedule, so leaf totals are pure multinomials over the
step sequences). This mirror re-derives those counts from an independent
implementation of the same state machines, in the same spirit as
scripts/srclint_mirror.py for the linter: if the two disagree, one of the
transliterations drifted.

    python3 scripts/schedules_mirror.py        # prints and checks all counts

Only the exhaustive tier is mirrored; the randomized tier uses the crate's
xoshiro256** stream and is covered in Rust by two-run digest equality.
"""
import sys
from copy import deepcopy

sys.setrecursionlimit(100000)

# ---------------------------------------------------------------- explorer

def explore(m0):
    stats = {"schedules": 0, "violated": 0}

    def dfs(m, violated):
        acts = m.actions()
        if not acts:
            stats["schedules"] += 1
            if violated or not m.done():
                stats["violated"] += 1
            return
        for a in acts:
            n = deepcopy(m)
            n.step(a)
            dfs(n, violated or n.bad)

    dfs(m0, False)
    return stats

# ------------------------------------------- PolicySwitch, locked (correct)

class PolicyLocked:
    """Installers: lock; read cur; write (epoch+1, pid); unlock.
    Readers: lock; read pair; unlock. Per-thread `sections` critical
    sections. Invariants: observed pairs were installed; epochs unique."""

    def __init__(self, installers=2, readers=2, sections=2):
        # thread: [is_installer, sec, step, reg]
        self.threads = [[True, 0, 0, 0] for _ in range(installers)] + \
                       [[False, 0, 0, 0] for _ in range(readers)]
        self.sections = sections
        self.lock = None
        self.cur = (0, 0)
        self.installed = {(0, 0)}
        self.epochs = {0}
        self.bad = False

    def actions(self):
        out = []
        for t, (_, sec, step, _) in enumerate(self.threads):
            if sec >= self.sections:
                continue
            if (self.lock is None) if step == 0 else (self.lock == t):
                out.append(t)
        return out

    def step(self, t):
        th = self.threads[t]
        if th[2] == 0:
            self.lock = t
            th[2] = 1
            return
        if th[0]:  # installer
            if th[2] == 1:
                th[3] = self.cur[0]
                th[2] = 2
            elif th[2] == 2:
                epoch, pid = th[3] + 1, t * 10 + th[1] + 1
                self.cur = (epoch, pid)
                if epoch in self.epochs:
                    self.bad = True
                self.epochs.add(epoch)
                self.installed.add((epoch, pid))
                th[2] = 3
            else:
                self.lock, th[1], th[2] = None, th[1] + 1, 0
        elif th[2] == 1:
            if self.cur not in self.installed:
                self.bad = True
            th[2] = 2
        else:
            self.lock, th[1], th[2] = None, th[1] + 1, 0

    def done(self):
        return self.lock is None and all(th[1] >= self.sections for th in self.threads)

# --------------------------------------------- PolicySwitch, torn (buggy)

class PolicyTorn:
    """Epoch and policy written as two independent unlocked steps.
    Installer: read epoch; write policy; write epoch. Reader: read epoch;
    read policy + validate the pair."""

    def __init__(self, installers=2, readers=2):
        # thread: [is_installer, step, reg]
        self.threads = [[True, 0, 0] for _ in range(installers)] + \
                       [[False, 0, 0] for _ in range(readers)]
        self.epoch = 0
        self.policy = 0
        self.installed = {(0, 0)}
        self.epochs = {0}
        self.bad = False

    @staticmethod
    def nsteps(th):
        return 3 if th[0] else 2

    def actions(self):
        return [t for t, th in enumerate(self.threads) if th[1] < self.nsteps(th)]

    def step(self, t):
        th = self.threads[t]
        pid = t * 10 + 1
        if th[0]:
            if th[1] == 0:
                th[2] = self.epoch
            elif th[1] == 1:
                self.policy = pid
            else:
                e = th[2] + 1
                self.epoch = e
                if e in self.epochs:
                    self.bad = True
                self.epochs.add(e)
                self.installed.add((e, pid))
        elif th[1] == 0:
            th[2] = self.epoch
        elif (th[2], self.policy) not in self.installed:
            self.bad = True
        th[1] += 1

    def done(self):
        return all(th[1] >= self.nsteps(th) for th in self.threads)

# ------------------------------------------------- worker request ledger

IDLE, HOLD, CRASH, RETIRED = range(4)

class Ledger:
    """run_batch + supervisor + close, abstracted. Exactly one reply per
    request; the buggy sweep consults the original batch instead of the
    not-yet-replied remainder and double-replies."""

    def __init__(self, requests, workers, batch_cap, max_attempts, buggy_sweep=False):
        self.R, self.B, self.MAX = requests, batch_cap, max_attempts
        self.buggy = buggy_sweep
        self.queue = []
        self.next_submit = 0
        self.replies = [0] * requests
        self.closed = False
        # worker: [state, batch, orig, computed, attempts, stranded]
        self.workers = [[IDLE, [], [], False, 0, []] for _ in range(workers)]
        self.bad = False

    def actions(self):
        out = []
        if self.next_submit < self.R:
            out.append(2000)
        if not self.closed:
            out.append(2001)
        if self.closed and self.queue and all(w[0] == RETIRED for w in self.workers):
            out.append(2002)
        for i, w in enumerate(self.workers):
            base = i * 10
            if w[0] == IDLE:
                if self.queue:
                    out.append(base + 0)                     # pop
                elif self.closed and self.next_submit >= self.R:
                    out.append(base + 1)                     # retire
            elif w[0] == HOLD:
                if not w[3]:
                    out.append(base + 2)                     # compute ok
                    out.append(base + (3 if w[4] < self.MAX else 4))
                elif w[1]:
                    out.append(base + 5)                     # reply one
                else:
                    out.append(base + 6)                     # finish
                if w[1]:
                    out.append(base + 7)                     # crash
            elif w[0] == CRASH:
                if w[5]:
                    out.append(base + 8)                     # sweep one
                else:
                    out.append(base + 9)                     # respawn
                    if self.closed:
                        out.append(base + 1)                 # retire
        return out

    def reply(self, k):
        self.replies[k] += 1
        if self.replies[k] > 1:
            self.bad = True

    def step(self, a):
        if a == 2000:
            k = self.next_submit
            self.next_submit += 1
            if self.closed:
                self.reply(k)      # typed reject is the one reply
            else:
                self.queue.append(k)
            return
        if a == 2001:
            self.closed = True
            return
        if a == 2002:
            self.reply(self.queue.pop(0))
            return
        i, op = divmod(a, 10)
        w = self.workers[i]
        if op == 0:
            take, self.queue = self.queue[: self.B], self.queue[self.B:]
            self.workers[i] = [HOLD, list(take), list(take), False, 0, []]
        elif op == 1:
            w[0] = RETIRED
        elif op == 2:
            w[3] = True
        elif op == 3:
            w[4] += 1
        elif op == 4:
            for k in w[1]:
                self.reply(k)
            self.workers[i] = [IDLE, [], [], False, 0, []]
        elif op == 5:
            self.reply(w[1].pop(0))
        elif op == 6:
            self.workers[i] = [IDLE, [], [], False, 0, []]
        elif op == 7:
            stranded = list(w[2]) if self.buggy else list(w[1])
            self.workers[i] = [CRASH, [], [], False, 0, stranded]
        elif op == 8:
            self.reply(w[5].pop(0))
        else:
            self.workers[i] = [IDLE, [], [], False, 0, []]

    def done(self):
        return (self.next_submit >= self.R and self.closed and not self.queue
                and all(w[0] == RETIRED for w in self.workers)
                and all(r == 1 for r in self.replies))


# ------------------------------------------------- sharded steal queue

class Steal:
    """Sharded work-stealing pop (PR 9 `ShardedQueue`): round-robin pushes,
    workers take from their home shard and steal from the first non-empty
    shard in sweep order when home is empty. The correct variant takes
    under the victim's lock (one atomic action); the racy variant peeks the
    victim's head and commits without re-checking — a stale commit serves a
    request another worker already took (double-pop), which the sticky
    invariant must catch. Requests left behind strand the run (`done`
    fails), so losses are caught too."""

    def __init__(self, requests=3, workers=2, shards=2, racy=False):
        self.R, self.S = requests, shards
        self.racy = racy
        self.shards = [[] for _ in range(shards)]
        self.rr = 0
        self.next_submit = 0
        self.replies = [0] * requests
        self.closed = False
        # worker: [retired, peeked (victim, id) or None]
        self.workers = [[False, None] for _ in range(workers)]
        self.bad = False

    def victim(self, i):
        home = i % self.S
        for k in range(1, self.S):
            j = (home + k) % self.S
            if self.shards[j]:
                return j
        return None

    def actions(self):
        out = []
        if self.next_submit < self.R:
            out.append(2000)
        if not self.closed:
            out.append(2001)
        for i, (retired, peek) in enumerate(self.workers):
            if retired:
                continue
            base = i * 10
            if peek is not None:
                out.append(base + 2)                     # commit stolen
                continue
            if self.shards[i % self.S]:
                out.append(base + 0)                     # take home
            elif self.victim(i) is not None:
                out.append(base + 1)                     # steal (peek if racy)
            elif self.closed and self.next_submit >= self.R and not any(self.shards):
                out.append(base + 3)                     # retire
        return out

    def reply(self, k):
        self.replies[k] += 1
        if self.replies[k] > 1:
            self.bad = True

    def step(self, a):
        if a == 2000:
            if self.closed:
                self.reply(self.next_submit)  # typed reject is the one reply
            else:
                self.shards[self.rr % self.S].append(self.next_submit)
                self.rr += 1
            self.next_submit += 1
            return
        if a == 2001:
            self.closed = True
            return
        i, op = divmod(a, 10)
        w = self.workers[i]
        if op == 0:
            self.reply(self.shards[i % self.S].pop(0))
        elif op == 1:
            j = self.victim(i)
            if self.racy:
                w[1] = (j, self.shards[j][0])
            else:
                self.reply(self.shards[j].pop(0))
        elif op == 2:
            j, k = w[1]
            w[1] = None
            if k in self.shards[j]:
                self.shards[j].remove(k)
            self.reply(k)
        else:
            w[0] = True

    def done(self):
        return (self.next_submit >= self.R and self.closed
                and not any(self.shards)
                and all(w[0] for w in self.workers)
                and all(r == 1 for r in self.replies))


# Exact counts asserted by rust/tests/schedules.rs.
EXPECTED = [
    ("locked 2x2 installers + 2x2 readers", PolicyLocked(), 2520, 0),
    ("torn 2 installers + 2 readers", PolicyTorn(), 25200, 25008),
    ("ledger R2 W1 B2 A1", Ledger(2, 1, 2, 1), 2899, 0),
    ("ledger R2 W1 B2 A1 buggy sweep", Ledger(2, 1, 2, 1, buggy_sweep=True), 2903, 32),
    ("ledger R3 W1 B2 A1", Ledger(3, 1, 2, 1), 112269, 0),
    ("steal R3 W2 S2", Steal(3, 2, 2), 314, 0),
    ("steal R3 W2 S2 racy", Steal(3, 2, 2, racy=True), 4722, 4134),
    ("steal R4 W2 S2", Steal(4, 2, 2), 1926, 0),
    ("steal R4 W2 S2 racy", Steal(4, 2, 2, racy=True), 67909, 63549),
]

if __name__ == "__main__":
    ok = True
    total = 0
    for name, model, schedules, violated in EXPECTED:
        s = explore(model)
        total += s["schedules"]
        mark = "ok" if (s["schedules"], s["violated"]) == (schedules, violated) else "MISMATCH"
        if mark != "ok":
            ok = False
        print(f"{name}: {s['schedules']} schedules, {s['violated']} violated "
              f"(expect {schedules}/{violated}) {mark}")
    print(f"exhaustive tier total: {total} schedules")
    sys.exit(0 if ok else 1)
