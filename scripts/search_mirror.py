#!/usr/bin/env python3
"""Independent transliteration of the NSGA machinery in
rust/src/search/nsga.rs, cross-checked against the checked-in fixture
rust/tests/fixtures/search_front.json — the same file the Rust test
`nsga_matches_checked_in_fixture` pins. If the two disagree, one of the
transliterations drifted (same spirit as scripts/srclint_mirror.py and
scripts/schedules_mirror.py).

    python3 scripts/search_mirror.py           # prints and checks everything

All fixture objectives are exact binary fractions, so Rust and Python
float arithmetic cannot diverge: every comparison below is exact
equality, not tolerance-based.
"""
import json
import math
import os
import sys

INF = math.inf

# ------------------------------------------------------------- machinery
# Candidates are (est_loss, power_norm) tuples or None (infeasible).


def dominates(a, b):
    """Strict Pareto dominance, both axes minimized."""
    return a[0] <= b[0] and a[1] <= b[1] and (a[0] < b[0] or a[1] < b[1])


def fast_nondominated_sort(objs):
    """Fronts of candidate indices, each in ascending index order; all
    infeasible candidates form one final front."""
    feasible = [i for i, o in enumerate(objs) if o is not None]
    infeasible = [i for i, o in enumerate(objs) if o is None]
    fronts = []
    if feasible:
        dominated_by = [0] * len(objs)
        dominates_list = [[] for _ in objs]
        for ai, a in enumerate(feasible):
            for b in feasible[ai + 1:]:
                if dominates(objs[a], objs[b]):
                    dominates_list[a].append(b)
                    dominated_by[b] += 1
                elif dominates(objs[b], objs[a]):
                    dominates_list[b].append(a)
                    dominated_by[a] += 1
        current = [i for i in feasible if dominated_by[i] == 0]
        while current:
            nxt = []
            for i in current:
                for j in dominates_list[i]:
                    dominated_by[j] -= 1
                    if dominated_by[j] == 0:
                        nxt.append(j)
            nxt.sort()
            fronts.append(current)
            current = nxt
    if infeasible:
        fronts.append(infeasible)
    return fronts


def crowding_distance(objs, front):
    """Crowding distances aligned with `front`'s positions. Boundaries are
    +inf; interior members accumulate normalized neighbour gaps per axis;
    objective sorts tie-break on candidate index."""
    d = [0.0] * len(front)
    if not front:
        return d
    if objs[front[0]] is None:
        return [INF] * len(front)
    for axis in range(2):
        def value(pos):
            return objs[front[pos]][axis]
        order = sorted(range(len(front)), key=lambda p: (value(p), front[p]))
        first, last = order[0], order[-1]
        d[first] = INF
        d[last] = INF
        rng = value(last) - value(first)
        if rng > 0.0:
            for w in range(len(order) - 2):
                prev, mid, nxt = order[w], order[w + 1], order[w + 2]
                d[mid] += (value(nxt) - value(prev)) / rng
    return d


def survivors(objs, n):
    """Whole fronts while they fit, then crowding-descending truncation
    with ascending-index tie-breaks."""
    keep = []
    for front in fast_nondominated_sort(objs):
        if len(keep) >= n:
            break
        room = n - len(keep)
        if len(front) <= room:
            keep.extend(front)
            continue
        d = crowding_distance(objs, front)
        order = sorted(range(len(front)), key=lambda p: (-d[p], front[p]))
        keep.extend(front[p] for p in order[:room])
    return keep


def hypervolume(points, ref_loss, ref_power):
    """2-D staircase area toward the reference point; members outside the
    reference box contribute nothing."""
    pts = sorted(p for p in points if p[0] < ref_loss and p[1] < ref_power)
    hv = 0.0
    best_power = ref_power
    for loss, power in pts:
        if power < best_power:
            hv += (ref_loss - loss) * (best_power - power)
            best_power = power
    return hv


# ------------------------------------------------------------ cross-check

def main():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(root, "rust", "tests", "fixtures", "search_front.json")
    with open(path) as f:
        fx = json.load(f)

    objs = [None if c is None else (c["est_loss"], c["power_norm"])
            for c in fx["candidates"]]
    ok = True

    def check(name, got, want):
        nonlocal ok
        mark = "ok" if got == want else "MISMATCH"
        if mark != "ok":
            ok = False
        print(f"{name}: {got} (expect {want}) {mark}")

    fronts = fast_nondominated_sort(objs)
    check("fronts", fronts, fx["expected_fronts"])

    want_crowding = [[INF if v is None else v for v in front]
                     for front in fx["expected_crowding"]]
    got_crowding = [crowding_distance(objs, front) for front in fronts]
    check("crowding", got_crowding, want_crowding)

    check("survivors(4)", survivors(objs, 4), fx["expected_survivors_4"])
    check("survivors(7)", survivors(objs, 7), fx["expected_survivors_7"])

    ref = fx["ref_point"]
    front0 = [objs[i] for i in fronts[0]]
    check("hypervolume(front0)",
          hypervolume(front0, ref["est_loss"], ref["power_norm"]),
          fx["expected_hypervolume_front0"])

    # internal consistency, independent of the fixture: no front member is
    # dominated by another member of the same or a later front
    for r, front in enumerate(fronts):
        for i in front:
            if objs[i] is None:
                continue
            for later in fronts[r:]:
                for j in later:
                    if j != i and objs[j] is not None and dominates(objs[j], objs[i]):
                        print(f"MISMATCH: candidate {i} in front {r} "
                              f"dominated by {j}")
                        ok = False
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
