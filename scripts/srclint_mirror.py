#!/usr/bin/env python3
"""Python mirror of `cvapprox srclint` (rust/src/analyze/).

The build container has no Rust toolchain, so — like the hermetic golden
generator — the linter keeps a python transliteration for offline
cross-checks. Run it from anywhere:

    python3 scripts/srclint_mirror.py [--root PATH] [--json out.json]

It must agree with the Rust pass rule-for-rule; divergence is a bug in
whichever side changed last. Keep the tokenizer and matchers in lockstep
with rust/src/analyze/{lexer,rules,report}.rs.
"""

import json
import os
import sys

# --- contract tables (mirror rust/src/analyze/contract.rs) -------------

ATOMIC_CONTRACT = {
    ("rust/src/coordinator/service.rs", "alive"): ["SeqCst"],
    ("rust/src/coordinator/service.rs", "stopping"): ["SeqCst"],
    ("rust/src/coordinator/service.rs", "done"): ["SeqCst"],
    ("rust/src/coordinator/service.rs", "next_id"): ["SeqCst"],
    ("rust/src/coordinator/service.rs", "batch_seq"): ["Relaxed"],
    ("rust/src/coordinator/service.rs", "class_queued"): ["SeqCst"],
    ("rust/src/coordinator/service.rs", "rr"): ["Relaxed"],
    ("rust/src/coordinator/service.rs", "idle_workers"): ["Relaxed"],
    ("rust/src/fault/inject.rs", "seq"): ["Relaxed"],
    ("rust/src/util/threadpool.rs", "CACHE"): ["Relaxed"],
    ("rust/src/util/threadpool.rs", "next"): ["Relaxed"],
    ("rust/src/nn/engine.rs", "num"): ["Relaxed"],
    ("rust/src/nn/engine.rs", "den"): ["Relaxed"],
    ("rust/src/nn/engine.rs", "n"): ["Relaxed"],
    ("rust/src/nn/engine.rs", "generation"): ["SeqCst"],
    ("rust/src/nn/plan.rs", "builds"): ["Relaxed"],
    ("rust/src/nn/plan.rs", "generation"): ["SeqCst"],
    ("rust/src/qos/governor.rs", "rung"): ["Acquire"],
    ("rust/src/qos/governor.rs", "stop"): ["Acquire", "Release"],
    ("rust/src/qos/governor.rs", "rung_gauge"): ["Release"],
    ("rust/src/qos/telemetry.rs", "head"): ["Release", "Acquire"],
    ("rust/src/qos/telemetry.rs", "lat_us"): ["Release", "Acquire"],
    ("rust/src/qos/telemetry.rs", "drained_head"): ["Relaxed"],
    ("rust/src/qos/telemetry.rs", "inflight"): ["Relaxed"],
    ("rust/src/qos/telemetry.rs", "depth_sum"): ["Relaxed"],
    ("rust/src/qos/telemetry.rs", "depth_n"): ["Relaxed"],
    ("rust/src/qos/telemetry.rs", "occ_pm_sum"): ["Relaxed"],
    ("rust/src/qos/telemetry.rs", "occ_n"): ["Relaxed"],
    ("rust/src/qos/telemetry.rs", "expired"): ["Relaxed"],
}

DETERMINISTIC_MODULES = [
    "rust/src/fault/inject.rs",
    "rust/src/util/rng.rs",
    "rust/src/util/prop.rs",
    "rust/src/nn/testutil.rs",
    "rust/src/search/mod.rs",
    "rust/src/search/genome.rs",
    "rust/src/search/evaluate.rs",
    "rust/src/search/nsga.rs",
]

HOT_PATH_DIRS = ["rust/src/coordinator/", "rust/src/fault/"]
SYNC_WRAPPER_FILE = "rust/src/util/sync.rs"
USER_INPUT_RECEIVERS = ["image", "logits", "requests", "batch"]
ENV_REGISTRY_BEGIN = "<!-- srclint:env-registry:begin -->"
ENV_REGISTRY_END = "<!-- srclint:env-registry:end -->"
ATOMIC_ORDERINGS = ["Relaxed", "Acquire", "Release", "AcqRel", "SeqCst"]
ATOMIC_METHODS = [
    "load", "store", "swap", "fetch_add", "fetch_sub", "fetch_and",
    "fetch_or", "fetch_xor", "fetch_min", "fetch_max", "fetch_update",
    "compare_exchange", "compare_exchange_weak",
]
WAIT_METHODS = ["wait", "wait_timeout", "wait_while", "wait_timeout_while"]

# --- tokenizer (mirror rust/src/analyze/lexer.rs) ----------------------

IDENT, PUNCT, NUM, STR, CHAR, LIFETIME, COMMENT = range(7)


def raw_string_start(cs, i):
    n = len(cs)
    j = i
    if j < n and cs[j] == "b":
        j += 1
    if j >= n or cs[j] != "r":
        return None
    j += 1
    hashes = 0
    while j < n and cs[j] == "#":
        hashes += 1
        j += 1
    if j < n and cs[j] == '"':
        return (j + 1, hashes)
    return None


def scan_char_body(cs, i):
    n = len(cs)
    while i < n:
        if cs[i] == "\\":
            i += 2
        elif cs[i] == "'":
            return i + 1
        else:
            i += 1
    return n


def tokenize(src):
    cs = src
    n = len(cs)
    out = []
    i = 0
    line = 1
    while i < n:
        c = cs[i]
        if c == "\n":
            line += 1
            i += 1
            continue
        if c.isspace():
            i += 1
            continue
        if c == "/" and i + 1 < n and cs[i + 1] == "/":
            start = i
            while i < n and cs[i] != "\n":
                i += 1
            out.append((COMMENT, cs[start:i], line))
            continue
        if c == "/" and i + 1 < n and cs[i + 1] == "*":
            start, start_line, depth = i, line, 1
            i += 2
            while i < n and depth > 0:
                if cs[i] == "\n":
                    line += 1
                    i += 1
                elif cs[i] == "/" and i + 1 < n and cs[i + 1] == "*":
                    depth += 1
                    i += 2
                elif cs[i] == "*" and i + 1 < n and cs[i + 1] == "/":
                    depth -= 1
                    i += 2
                else:
                    i += 1
            out.append((COMMENT, cs[start:i], start_line))
            continue
        raw = raw_string_start(cs, i)
        if raw is not None:
            body_at, hashes = raw
            start, start_line = i, line
            i = body_at
            while i < n:
                if cs[i] == "\n":
                    line += 1
                    i += 1
                    continue
                if cs[i] == '"' and i + hashes < n and all(
                    h == "#" for h in cs[i + 1 : i + 1 + hashes]
                ):
                    i += 1 + hashes
                    break
                i += 1
            out.append((STR, cs[start : min(i, n)], start_line))
            continue
        if c == '"' or (c == "b" and i + 1 < n and cs[i + 1] == '"'):
            start, start_line = i, line
            i += 2 if c == "b" else 1
            while i < n:
                if cs[i] == "\\":
                    i += 2
                elif cs[i] == '"':
                    i += 1
                    break
                else:
                    if cs[i] == "\n":
                        line += 1
                    i += 1
            out.append((STR, cs[start : min(i, n)], start_line))
            continue
        if c == "b" and i + 1 < n and cs[i + 1] == "'":
            start = i
            i = scan_char_body(cs, i + 2)
            out.append((CHAR, cs[start : min(i, n)], line))
            continue
        if c == "'":
            if i + 1 < n and cs[i + 1] == "\\":
                start = i
                i = scan_char_body(cs, i + 1)
                out.append((CHAR, cs[start : min(i, n)], line))
                continue
            if i + 2 < n and cs[i + 2] == "'" and cs[i + 1] != "'":
                out.append((CHAR, cs[i : i + 3], line))
                i += 3
                continue
            if i + 1 < n and (cs[i + 1].isalpha() or cs[i + 1] == "_"):
                start = i
                i += 1
                while i < n and (cs[i].isalnum() or cs[i] == "_"):
                    i += 1
                out.append((LIFETIME, cs[start:i], line))
                continue
            out.append((PUNCT, "'", line))
            i += 1
            continue
        if c.isdigit():
            start = i
            radix = c == "0" and i + 1 < n and cs[i + 1] in "xXbBoO"
            i += 1
            while i < n:
                ch = cs[i]
                if ch.isalnum() or ch == "_":
                    i += 1
                elif ch == "." and i + 1 < n and cs[i + 1].isdigit() and not radix:
                    i += 1
                elif ch in "+-" and not radix and cs[i - 1] in "eE":
                    i += 1
                else:
                    break
            out.append((NUM, cs[start:i], line))
            continue
        if c.isalpha() or c == "_":
            start = i
            i += 1
            while i < n and (cs[i].isalnum() or cs[i] == "_"):
                i += 1
            out.append((IDENT, cs[start:i], line))
            continue
        out.append((PUNCT, c, line))
        i += 1
    return out


# --- rules (mirror rust/src/analyze/rules.rs) --------------------------


def match_forward(code, open_idx, op, cl):
    depth = 0
    for k in range(open_idx, len(code)):
        kind, text, _ = code[k]
        if kind == PUNCT and text == op:
            depth += 1
        elif kind == PUNCT and text == cl:
            depth -= 1
            if depth == 0:
                return k
    return None


def find_test_regions(code):
    spans = []
    i = 0
    while i + 1 < len(code):
        if not (code[i][:2] == (PUNCT, "#") and code[i + 1][:2] == (PUNCT, "[")):
            i += 1
            continue
        close = match_forward(code, i + 1, "[", "]")
        if close is None:
            break
        is_test = any(t[0] == IDENT and t[1] == "test" for t in code[i + 2 : close])
        j = close + 1
        if is_test:
            while (
                j + 1 < len(code)
                and code[j][:2] == (PUNCT, "#")
                and code[j + 1][:2] == (PUNCT, "[")
            ):
                c2 = match_forward(code, j + 1, "[", "]")
                if c2 is None:
                    break
                j = c2 + 1
            depth = 0
            body = None
            while j < len(code):
                kind, text, _ = code[j]
                if kind == PUNCT and text in "([":
                    depth += 1
                elif kind == PUNCT and text in ")]":
                    depth -= 1
                elif depth == 0 and kind == PUNCT and text == "{":
                    body = j
                    break
                elif depth == 0 and kind == PUNCT and text == ";":
                    break
                j += 1
            if body is not None:
                end = match_forward(code, body, "{", "}")
                if end is not None:
                    spans.append((code[body][2], code[end][2]))
                    i = end + 1
                    continue
        i = close + 1
    return spans


def vars_in(text):
    out = []
    i = 0
    needle = "CVAPPROX"
    while i + len(needle) <= len(text):
        before = i > 0 and (text[i - 1].isalnum() or text[i - 1] == "_")
        if not before and text[i : i + len(needle)] == needle:
            j = i + len(needle)
            while j < len(text) and (
                (text[j].isupper() and text[j].isascii()) or text[j].isdigit() or text[j] == "_"
            ):
                j += 1
            name = text[i:j].rstrip("_")
            if name != "CVAPPROX":
                out.append(name)
            i = j
        else:
            i += 1
    return out


def parse_allow(s):
    if not s.startswith("allow("):
        return None
    body = s[len("allow(") :]
    close = body.rfind(")")
    if close < 0 or "," not in body[:close]:
        return None
    rule, reason = body[:close].split(",", 1)
    rule, reason = rule.strip(), reason.strip()
    if rule in ("R1", "R2", "R3", "R4", "R5") and reason:
        return (rule, reason)
    return None


def lint_source(relpath, src):
    toks = tokenize(src)
    code = [t for t in toks if t[0] != COMMENT]
    regions = find_test_regions(code)
    is_test_file = relpath.startswith("rust/tests/")

    def in_test(line):
        return is_test_file or any(a <= line <= b for (a, b) in regions)

    findings, sups, env_refs = [], [], []

    for t in toks:
        if t[0] != COMMENT:
            continue
        body = t[1].lstrip("/*! \t")
        if not body.startswith("srclint:"):
            continue
        rest = body[len("srclint:") :].strip()
        parsed = parse_allow(rest)
        if parsed:
            sups.append((relpath, t[2], parsed[0], parsed[1]))
        else:
            findings.append((relpath, t[2], "SUP", "malformed suppression"))

    # R1
    if relpath != SYNC_WRAPPER_FILE:
        i = 0
        while i + 2 < len(code):
            if not (code[i][:2] == (PUNCT, ".") and code[i + 1][0] == IDENT):
                i += 1
                continue
            m = code[i + 1][1]
            is_lock = m == "lock"
            is_wait = m in WAIT_METHODS
            if not (is_lock or is_wait) or code[i + 2][:2] != (PUNCT, "("):
                i += 1
                continue
            close = match_forward(code, i + 2, "(", ")")
            if close is None:
                break
            arity_ok = close == i + 3 if is_lock else close > i + 3
            j = close + 1
            if (
                arity_ok
                and j + 2 < len(code)
                and code[j][:2] == (PUNCT, ".")
                and code[j + 1][0] == IDENT
                and code[j + 1][1] in ("unwrap", "expect")
                and code[j + 2][:2] == (PUNCT, "(")
            ):
                line = code[j + 1][2]
                if not in_test(line):
                    findings.append((relpath, line, "R1", f"bare .{m}().{code[j+1][1]}()"))
            i = j

    # R2
    if relpath.startswith("rust/src/"):
        for i in range(len(code)):
            if not (
                code[i][:2] == (IDENT, "Ordering")
                and i + 3 < len(code)
                and code[i + 1][:2] == (PUNCT, ":")
                and code[i + 2][:2] == (PUNCT, ":")
                and code[i + 3][0] == IDENT
                and code[i + 3][1] in ATOMIC_ORDERINGS
            ):
                continue
            variant = code[i + 3][1]
            line = code[i][2]
            if in_test(line):
                continue
            depth = 0
            open_idx = None
            for j in range(i - 1, -1, -1):
                kind, text, _ = code[j]
                if kind == PUNCT and text == ")":
                    depth += 1
                elif kind == PUNCT and text == "(":
                    if depth == 0:
                        open_idx = j
                        break
                    depth -= 1
                elif depth == 0 and kind == PUNCT and text in ";{}":
                    break
            if open_idx is None:
                findings.append((relpath, line, "R2", f"Ordering::{variant} outside call"))
                continue
            if open_idx == 0 or code[open_idx - 1][0] != IDENT:
                findings.append((relpath, line, "R2", f"Ordering::{variant} not a method call"))
                continue
            method = code[open_idx - 1][1]
            if method not in ATOMIC_METHODS:
                findings.append((relpath, line, "R2", f"Ordering::{variant} passed to {method}"))
                continue
            recv = None
            if open_idx >= 3 and code[open_idx - 2][:2] == (PUNCT, "."):
                r = open_idx - 3
                if code[r][:2] == (PUNCT, "]"):
                    d = 0
                    found = None
                    for k in range(r, -1, -1):
                        if code[k][:2] == (PUNCT, "]"):
                            d += 1
                        elif code[k][:2] == (PUNCT, "["):
                            d -= 1
                            if d == 0:
                                found = k
                                break
                    if found is not None and found >= 1:
                        r = found - 1
                    else:
                        r = None
                if r is not None and code[r][0] == IDENT:
                    recv = code[r][1]
            if recv is None:
                findings.append((relpath, line, "R2", f"cannot resolve receiver of {method}"))
                continue
            allowed = ATOMIC_CONTRACT.get((relpath, recv))
            if allowed is None:
                findings.append((relpath, line, "R2", f"atomic {recv} not in contract"))
            elif variant not in allowed:
                findings.append(
                    (relpath, line, "R2", f"{recv}.{method}(Ordering::{variant}) not allowed")
                )

    # R3
    if any(relpath.startswith(d) for d in HOT_PATH_DIRS):
        caught = []
        for i in range(len(code)):
            if code[i][:2] == (IDENT, "catch_unwind") and i + 1 < len(code) and code[i + 1][
                :2
            ] == (PUNCT, "("):
                close = match_forward(code, i + 1, "(", ")")
                if close is not None:
                    caught.append((code[i][2], code[close][2]))

        def exempt(line):
            return in_test(line) or any(a <= line <= b for (a, b) in caught)

        for i in range(len(code)):
            kind, text, line = code[i]
            if (
                kind == PUNCT
                and text == "."
                and i + 2 < len(code)
                and code[i + 1][0] == IDENT
                and code[i + 1][1] in ("unwrap", "expect")
                and code[i + 2][:2] == (PUNCT, "(")
                and not exempt(code[i + 1][2])
            ):
                findings.append((relpath, code[i + 1][2], "R3", f".{code[i+1][1]}() in hot path"))
            if (
                kind == IDENT
                and text == "panic"
                and i + 1 < len(code)
                and code[i + 1][:2] == (PUNCT, "!")
                and not exempt(line)
            ):
                findings.append((relpath, line, "R3", "panic! in hot path"))
            if (
                kind == IDENT
                and text in USER_INPUT_RECEIVERS
                and i + 1 < len(code)
                and code[i + 1][:2] == (PUNCT, "[")
                and not exempt(line)
            ):
                findings.append((relpath, line, "R3", f"{text}[..] indexing on user input"))

    # R4
    if relpath in DETERMINISTIC_MODULES:
        for kind, text, line in code:
            if kind == IDENT and text in ("Instant", "SystemTime"):
                findings.append((relpath, line, "R4", f"{text} in deterministic module"))

    for t in toks:
        if t[0] == STR and not in_test(t[2]):
            for v in vars_in(t[1]):
                env_refs.append((v, t[2]))
    return findings, sups, env_refs


def apply_suppressions(findings, sups):
    kept, suppressed = [], 0
    for f in findings:
        hit = f[2] != "SUP" and any(
            s[0] == f[0] and s[2] == f[2] and f[1] in (s[1], s[1] + 1) for s in sups
        )
        if hit:
            suppressed += 1
        else:
            kept.append(f)
    return kept, suppressed


def extract_env_vars(text):
    out = []
    for i, line in enumerate(text.splitlines()):
        for v in vars_in(line):
            out.append((v, i + 1))
    return out


# --- tree walk + R5 (mirror rust/src/analyze/report.rs) ----------------


def collect(root, sub, ext):
    base = os.path.join(root, sub)
    found = []
    if not os.path.isdir(base):
        return found
    for dirpath, dirnames, filenames in os.walk(base):
        dirnames.sort()
        for f in sorted(filenames):
            if f.endswith("." + ext):
                found.append(os.path.join(dirpath, f))
    return found


def run_lint(root):
    findings, sups = [], []
    code_vars = {}
    files = 0
    rs = collect(root, "rust/src", "rs") + collect(root, "rust/tests", "rs") + collect(
        root, "benches", "rs"
    )
    for path in sorted(rs):
        rel = os.path.relpath(path, root).replace(os.sep, "/")
        with open(path, encoding="utf-8") as fh:
            src = fh.read()
        f, s, env = lint_source(rel, src)
        findings += f
        sups += s
        for v, line in env:
            code_vars.setdefault(v, (rel, line))
        files += 1
    raw = collect(root, "scripts", "sh") + collect(root, ".github/workflows", "yml")
    for path in sorted(raw):
        rel = os.path.relpath(path, root).replace(os.sep, "/")
        with open(path, encoding="utf-8") as fh:
            text = fh.read()
        for v, line in extract_env_vars(text):
            code_vars.setdefault(v, (rel, line))
        files += 1

    readme = os.path.join(root, "README.md")
    with open(readme, encoding="utf-8") as fh:
        rd = fh.read()
    b, e = rd.find(ENV_REGISTRY_BEGIN), rd.find(ENV_REGISTRY_END)
    if b < 0 or e < 0:
        findings.append(("README.md", 1, "R5", "registry markers missing"))
        registry = {}
    else:
        base_line = rd[:b].count("\n") + 1
        registry = {}
        for v, line in extract_env_vars(rd[b:e]):
            registry.setdefault(v, base_line + line - 1)
        for v, (rel, line) in sorted(code_vars.items()):
            if v not in registry:
                findings.append((rel, line, "R5", f"env var {v} missing from registry"))
        for v, line in sorted(registry.items()):
            if v not in code_vars:
                findings.append(("README.md", line, "R5", f"registry lists stale {v}"))

    kept, suppressed = apply_suppressions(findings, sups)
    kept.sort(key=lambda f: (f[0], f[1], f[2]))
    return {
        "files_scanned": files,
        "findings": kept,
        "suppressed": suppressed,
        "suppressions": sups,
        "code_vars": code_vars,
    }


def main():
    argv = sys.argv[1:]
    root = "."
    out_json = None
    i = 0
    while i < len(argv):
        if argv[i] == "--root":
            root = argv[i + 1]
            i += 2
        elif argv[i] == "--json":
            out_json = argv[i + 1]
            i += 2
        else:
            print(f"unknown arg {argv[i]}", file=sys.stderr)
            return 2
    rep = run_lint(root)
    for f in rep["findings"]:
        print(f"{f[0]}:{f[1]} [{f[2]}] {f[3]}")
    print(
        f"srclint(py): {len(rep['findings'])} finding(s), "
        f"{rep['suppressed']} suppressed, {rep['files_scanned']} file(s) scanned"
    )
    if out_json:
        with open(out_json, "w", encoding="utf-8") as fh:
            json.dump(
                {
                    "tool": "srclint-mirror",
                    "files_scanned": rep["files_scanned"],
                    "suppressed": rep["suppressed"],
                    "findings": [
                        {"file": f[0], "line": f[1], "rule": f[2], "message": f[3]}
                        for f in rep["findings"]
                    ],
                },
                fh,
                indent=2,
            )
    return 1 if rep["findings"] else 0


if __name__ == "__main__":
    sys.exit(main())
