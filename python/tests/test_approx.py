"""Approximate-multiplier identities vs bit-level partial-product models.

The identities in kernels/approx.py (AM = W*A - eps) are the foundation of
everything (kernels, numpy reference, rust engine). Here they are checked
against *structural* models that build the approximate product the way the
hardware does — by summing the partial products the circuit actually keeps.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from compile.kernels import approx

u8 = st.integers(0, 255)


def am_perforated_bits(w: int, a: int, m: int) -> int:
    """eq. (2): keep partial products i not in [0, m) (s=0)."""
    return sum(w * ((a >> i) & 1) << i for i in range(m, 8))


def am_recursive_bits(w: int, a: int, m: int) -> int:
    """eq. (5): drop the W_L*A_L sub-product."""
    wh, wl = w >> m, w & ((1 << m) - 1)
    ah, al = a >> m, a & ((1 << m) - 1)
    return (wh * ah << (2 * m)) + ((wh * al + wl * ah) << m)


def am_truncated_bits(w: int, a: int, m: int) -> int:
    """eq. (7): drop partial-product bits w_j*a_i with i+j < m."""
    out = 0
    for i in range(8):
        for j in range(8):
            if i + j >= m:
                out += ((w >> j) & 1) * ((a >> i) & 1) << (i + j)
    return out


BITS = {"perforated": am_perforated_bits, "recursive": am_recursive_bits,
        "truncated": am_truncated_bits}


def _am_jnp(family, w, a, m):
    return int(approx.am(family, jnp.int32(w), jnp.int32(a), jnp.int32(m)))


@pytest.mark.parametrize("family", ["perforated", "recursive", "truncated"])
@pytest.mark.parametrize("m", [1, 2, 3, 4, 5, 6, 7])
def test_identity_matches_bit_model_sampled(family, m):
    rng = np.random.default_rng(42 + m)
    ws = rng.integers(0, 256, 300)
    as_ = rng.integers(0, 256, 300)
    w_arr = jnp.asarray(ws, jnp.int32)
    a_arr = jnp.asarray(as_, jnp.int32)
    got = np.asarray(approx.am(family, w_arr, a_arr, jnp.int32(m)))
    want = np.array([BITS[family](int(w), int(a), m) for w, a in zip(ws, as_)])
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("family", ["perforated", "recursive", "truncated"])
def test_identity_exhaustive_one_m(family):
    """Full 256x256 operand sweep at a mid m (rust covers all m exhaustively)."""
    m = {"perforated": 2, "recursive": 3, "truncated": 6}[family]
    w, a = np.meshgrid(np.arange(256), np.arange(256), indexing="ij")
    got = np.asarray(approx.am(family, jnp.asarray(w, jnp.int32),
                               jnp.asarray(a, jnp.int32), jnp.int32(m)))
    # vectorized bit models
    if family == "perforated":
        want = w * (a >> m << m)
    elif family == "recursive":
        want = w * a - (w & ((1 << m) - 1)) * (a & ((1 << m) - 1))
    else:
        want = np.zeros_like(w)
        for i in range(8):
            for j in range(8):
                if i + j >= m:
                    want += ((w >> j) & 1) * ((a >> i) & 1) << (i + j)
    np.testing.assert_array_equal(got, want)


@given(w=u8, a=u8, m=st.integers(1, 7))
@settings(max_examples=300, deadline=None)
def test_error_nonnegative_and_bounded(w, a, m):
    """eps >= 0 (all three drop positive partial products) and AM <= W*A."""
    for family in ("perforated", "recursive", "truncated"):
        e = int(approx.err(family, jnp.int32(w), jnp.int32(a), jnp.int32(m)))
        assert 0 <= e <= w * a


@given(w=u8, a=u8, m=st.integers(1, 7))
@settings(max_examples=200, deadline=None)
def test_truncated_error_le_perforated(w, a, m):
    """Truncation keeps a superset of perforation's partial-product bits."""
    et = int(approx.err("truncated", jnp.int32(w), jnp.int32(a), jnp.int32(m)))
    ep = int(approx.err("perforated", jnp.int32(w), jnp.int32(a), jnp.int32(m)))
    assert et <= ep


@given(w=u8, a=u8)
@settings(max_examples=100, deadline=None)
def test_m_zero_is_exact(w, a):
    for family in ("perforated", "recursive", "truncated"):
        assert int(approx.am(family, jnp.int32(w), jnp.int32(a), jnp.int32(0))) == w * a


@given(w=u8, m=st.integers(1, 7))
@settings(max_examples=200, deadline=None)
def test_w_hat_is_mean_truncation_error(w, m):
    """What (eq. 24) equals the empirical mean of eps_T over all 256 A values."""
    a = jnp.arange(256, dtype=jnp.int32)
    eps = np.asarray(approx.err("truncated", jnp.int32(w), a, jnp.int32(m)))
    what_q1 = int(approx.w_hat_q1(jnp.int32(w), jnp.int32(m)))
    assert what_q1 == round(2 * eps.mean() * 1e9) / 1e9 * 1 or abs(
        what_q1 / 2 - eps.mean()) < 1e-9


@given(a=u8, m=st.integers(1, 7))
@settings(max_examples=200, deadline=None)
def test_xvar_definitions(a, m):
    mask = (1 << m) - 1
    xp = int(approx.xvar("perforated", jnp.int32(a), jnp.int32(m)))
    xr = int(approx.xvar("recursive", jnp.int32(a), jnp.int32(m)))
    xt = int(approx.xvar("truncated", jnp.int32(a), jnp.int32(m)))
    assert xp == xr == (a & mask)
    assert xt == (1 if (a & mask) else 0)
