"""Model graph + quantized forward: shape inference, im2col, float-vs-quant."""

import numpy as np
import pytest

import jax.numpy as jnp

from compile import datasets, model, nets, quant


@pytest.mark.parametrize("net", list(nets.NETS))
def test_shape_inference_all_nets(net):
    nodes = nets.NETS[net](10)
    shapes = model.infer_shapes(nodes)
    assert shapes[-1] == (1, 1, 10)
    for i, n in enumerate(nodes):
        if n.op == "add":
            assert shapes[n.inputs[0]] == shapes[n.inputs[1]]
        if n.op == "conv":
            cin = shapes[n.inputs[0]][2]
            assert cin % n.groups == 0


@pytest.mark.parametrize("net", list(nets.NETS))
def test_float_forward_runs(net):
    nodes = nets.NETS[net](10)
    params = model.init_params(nodes, 0)
    x = jnp.asarray(np.random.default_rng(0).uniform(0, 1, (2, 32, 32, 3)),
                    jnp.float32)
    logits = model.float_forward(nodes, params, x)
    assert logits.shape == (2, 10)
    assert np.isfinite(np.asarray(logits)).all()


def test_im2col_matches_lax_conv():
    """Quantized conv via im2col+GEMM == float conv on dequantized operands
    (when the quantization grid is the data grid, i.e. no rounding)."""
    rng = np.random.default_rng(2)
    h, w, cin, cout, k = 8, 8, 3, 4, 3
    a_q = rng.integers(0, 256, (h, w, cin)).astype(np.uint8)
    w_q = rng.integers(0, 256, (cout, k * k * cin)).astype(np.uint8)
    zp_a, zp_w = 10, 20
    cols = model.im2col(a_q, k, 1, 1, zp_a)
    acc = (w_q.astype(np.int64) - zp_w) @ (cols.astype(np.int64) - zp_a)
    # float path
    x = (a_q.astype(np.float32) - zp_a)[None]
    wf = (w_q.astype(np.float32) - zp_w).reshape(cout, k, k, cin)
    wf = wf.transpose(1, 2, 3, 0)  # HWIO
    import jax
    y = jax.lax.conv_general_dilated(
        jnp.asarray(x), jnp.asarray(wf), (1, 1), [(1, 1), (1, 1)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    # NOTE: im2col pads with zp which dequantizes to real 0 — conv pads with 0.
    got = acc.T.reshape(h, w, cout)
    np.testing.assert_allclose(got, np.asarray(y)[0], rtol=0, atol=1e-3)


def test_zero_point_expansion_identity():
    """approx_gemm(exact) == sum (W-zw)(A-za) + bias."""
    rng = np.random.default_rng(3)
    w_q = rng.integers(0, 256, (5, 18)).astype(np.uint8)
    a_q = rng.integers(0, 256, (18, 7)).astype(np.uint8)
    bias = rng.integers(-1000, 1000, 5).astype(np.int32)
    zw, za = 13, 97
    acc = model.approx_gemm("exact", 0, False, w_q, a_q, zw, za, bias)
    want = ((w_q.astype(np.int64) - zw) @ (a_q.astype(np.int64) - za)
            + bias[:, None])
    np.testing.assert_array_equal(acc, want)


def test_quantized_forward_close_to_float():
    """Quantized exact inference tracks the float model on a tiny net."""
    nodes = nets.NETS["mininet"](10)
    params = model.init_params(nodes, 1)
    calib, _, _ = datasets.load("synth10", "calib")
    qm = model.quantize_model("t", nodes, params, calib[:64])
    imgs, _, _ = datasets.load("synth10", "calib")
    agree = 0
    for i in range(10):
        fl = np.asarray(model.float_forward(nodes, params,
                                            jnp.asarray(imgs[i:i + 1])))[0]
        q = quant.quantize(imgs[i], 1 / 255.0, 0)
        qg = qm.forward(q, "exact", 0, False)
        agree += int(fl.argmax() == qg.argmax())
    assert agree >= 8  # untrained logits are near-ties; allow slack


def test_cv_reduces_logit_error_on_real_net():
    """On a real net, ||logits_cv - logits_exact|| < ||logits_raw - logits_exact||."""
    nodes = nets.NETS["mininet"](10)
    params = model.init_params(nodes, 4)
    calib, _, _ = datasets.load("synth10", "calib")
    qm = model.quantize_model("t", nodes, params, calib[:64])
    q = quant.quantize(calib[5], 1 / 255.0, 0)
    exact = qm.forward(q, "exact", 0, False)
    worse = better = 0
    for fam, m in (("perforated", 2), ("truncated", 6), ("recursive", 4)):
        raw = np.linalg.norm(qm.forward(q, fam, m, False) - exact)
        cv = np.linalg.norm(qm.forward(q, fam, m, True) - exact)
        if cv < raw:
            better += 1
        else:
            worse += 1
    assert better >= 2, (better, worse)


@pytest.mark.parametrize("net", ["shufflenet", "inceptionnet"])
def test_grouped_and_concat_paths_quantized(net):
    """The exotic ops (groups, shuffle, concat) run and give stable shapes."""
    nodes = nets.NETS[net](10)
    params = model.init_params(nodes, 2)
    calib, _, _ = datasets.load("synth10", "calib")
    qm = model.quantize_model("t", nodes, params, calib[:32])
    q = quant.quantize(calib[0], 1 / 255.0, 0)
    out = qm.forward(q, "recursive", 3, True)
    assert out.shape == (10,)
    assert np.isfinite(out).all()
