"""Quantization primitives: roundtrips, rounding rules, calibration."""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import quant


@given(lo=st.floats(-50, 0), hi=st.floats(0.01, 50))
@settings(max_examples=100, deadline=None)
def test_qparams_cover_range(lo, hi):
    s, zp = quant.choose_qparams(lo, hi)
    assert 0 <= zp <= 255
    # representable range covers [lo, hi] with one-step slack
    assert (0 - zp) * s <= lo + s + 1e-6
    assert (255 - zp) * s >= hi - s - 1e-6


@given(st.lists(st.floats(-10, 10), min_size=4, max_size=64))
@settings(max_examples=100, deadline=None)
def test_quant_roundtrip_error_bounded(vals):
    x = np.array(vals, np.float32)
    s, zp = quant.choose_qparams(x.min(), x.max())
    q = quant.quantize(x, s, zp)
    back = quant.dequantize(q, s, zp)
    assert np.max(np.abs(back - x)) <= s * 0.5 + 1e-5


def test_zero_exactly_representable():
    s, zp = quant.choose_qparams(-3.7, 9.2)
    assert quant.dequantize(np.array([zp], np.uint8), s, zp)[0] == 0.0


def test_round_half_away():
    x = np.array([0.5, 1.5, -0.5, -1.5, 2.4, -2.4])
    np.testing.assert_array_equal(quant.round_half_away(x),
                                  [1, 2, -1, -2, 2, -2])


def test_requantize_clamps_and_rounds():
    acc = np.array([-100000, 0, 100000], np.int64)
    q = quant.requantize(acc, 0.01, 128)
    np.testing.assert_array_equal(q, [0, 128, 255])
    q2 = quant.requantize(np.array([50], np.int64), 0.01, 128)  # 0.5 -> 1
    assert q2[0] == 129


def test_bias_quantization():
    b = np.array([0.05, -0.02])
    bq = quant.quantize_bias(b, 0.01, 0.01)
    np.testing.assert_array_equal(bq, [500, -200])


def test_calibrator_percentile_clips_outliers():
    cal = quant.Calibrator(percentile=99.0)
    x = np.concatenate([np.random.default_rng(0).uniform(0, 1, 10000), [1000.0]])
    cal.observe(x)
    s, zp = cal.qparams()
    assert s < 0.02  # outlier did not blow up the scale
