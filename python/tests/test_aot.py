"""AOT lowering: HLO text is produced, parseable-looking, and m is dynamic."""

import functools

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import aot
from compile.kernels import approx, gemm


@pytest.mark.parametrize("family", approx.FAMILIES)
@pytest.mark.parametrize("variant", ["pallas", "fast"])
def test_lowering_produces_hlo_text(family, variant):
    fn = gemm.pallas_tile_gemm if variant == "pallas" else gemm.jnp_tile_gemm
    m = jax.ShapeDtypeStruct((1,), jnp.int32)
    w = jax.ShapeDtypeStruct((16, 16), jnp.int32)
    a = jax.ShapeDtypeStruct((16, 16), jnp.int32)
    text = aot.to_hlo_text(jax.jit(functools.partial(fn, family)).lower(m, w, a))
    assert "ENTRY" in text and "HloModule" in text
    # 4 outputs in a tuple
    assert "tuple" in text.lower()


def test_one_artifact_serves_all_m():
    """The same jitted computation gives correct results for every m —
    the property that lets rust keep ONE executable per family."""
    fn = jax.jit(functools.partial(gemm.jnp_tile_gemm, "perforated"))
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.integers(0, 256, (8, 8)), jnp.int32)
    a = jnp.asarray(rng.integers(0, 256, (8, 8)), jnp.int32)
    outs = {}
    for m in (1, 2, 3):
        am_acc = np.asarray(fn(jnp.array([m], jnp.int32), w, a)[0])
        outs[m] = am_acc
    assert not np.array_equal(outs[1], outs[3])
    # m=3 error >= m=1 error elementwise
    exact = np.asarray(w) @ np.asarray(a)
    assert ((exact - outs[3]) >= (exact - outs[1])).all()


def test_golden_points_cover_all_families():
    fams = {f for f, _, _ in aot.GOLDEN_POINTS}
    assert fams == set(approx.FAMILIES)
    assert any(cv for _, _, cv in aot.GOLDEN_POINTS)
    assert any(not cv for _, _, cv in aot.GOLDEN_POINTS)
