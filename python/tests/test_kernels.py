"""Pallas kernels vs the pure-jnp oracle: shapes/dtypes/m swept by hypothesis.

The Pallas tile kernel (interpret=True) and the identity-based fast path must
agree *bit-exactly* with ref.gemm_parts for every family, m, and tile shape.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from compile.kernels import approx, gemm, ref


def _run(family, m, w, a):
    mm = jnp.array([m], jnp.int32)
    wj, aj = jnp.asarray(w), jnp.asarray(a)
    want = ref.gemm_parts(family, wj, aj, m)
    got_p = gemm.pallas_tile_gemm(family, mm, wj, aj)
    got_f = gemm.jnp_tile_gemm(family, mm, wj, aj)
    for key, gp, gf in zip(("am_acc", "sum_x", "sum_a", "sum_w"), got_p, got_f):
        np.testing.assert_array_equal(np.asarray(gp), np.asarray(want[key]),
                                      err_msg=f"pallas {family} m={m} {key}")
        np.testing.assert_array_equal(np.asarray(gf), np.asarray(want[key]),
                                      err_msg=f"fast {family} m={m} {key}")


@given(
    family=st.sampled_from(approx.FAMILIES),
    m=st.integers(0, 7),
    tm=st.integers(1, 24),
    tk=st.integers(1, 48),
    tn=st.integers(1, 24),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=60, deadline=None)
def test_kernels_match_oracle(family, m, tm, tk, tn, seed):
    rng = np.random.default_rng(seed)
    w = rng.integers(0, 256, (tm, tk)).astype(np.int32)
    a = rng.integers(0, 256, (tk, tn)).astype(np.int32)
    _run(family, m, w, a)


@pytest.mark.parametrize("family", approx.FAMILIES)
def test_kernels_artifact_tile_shape(family):
    """The exact shape the AOT artifacts are lowered at."""
    rng = np.random.default_rng(1)
    w = rng.integers(0, 256, (gemm.TM, gemm.TK)).astype(np.int32)
    a = rng.integers(0, 256, (gemm.TK, gemm.TN)).astype(np.int32)
    m = {"exact": 0, "perforated": 2, "recursive": 3, "truncated": 6}[family]
    _run(family, m, w, a)


@pytest.mark.parametrize("family", ["perforated", "recursive", "truncated"])
@pytest.mark.parametrize("m", [1, 4, 7])
def test_extreme_operands(family, m):
    """All-zero, all-255, and identity-ish patterns."""
    for val_w, val_a in ((0, 0), (255, 255), (0, 255), (255, 0), (1, 1)):
        w = np.full((8, 16), val_w, np.int32)
        a = np.full((16, 8), val_a, np.int32)
        _run(family, m, w, a)


def test_zero_padding_is_error_free():
    """Zero rows/cols contribute nothing: the coordinator's K-padding is exact."""
    rng = np.random.default_rng(3)
    w = rng.integers(0, 256, (8, 16)).astype(np.int32)
    a = rng.integers(0, 256, (16, 8)).astype(np.int32)
    wp = np.concatenate([w, np.zeros((8, 16), np.int32)], axis=1)
    ap = np.concatenate([a, np.zeros((16, 8), np.int32)], axis=0)
    for family in ("perforated", "recursive", "truncated"):
        for m in (1, 5, 7):
            base = ref.gemm_parts(family, jnp.asarray(w), jnp.asarray(a), m)
            padded = ref.gemm_parts(family, jnp.asarray(wp), jnp.asarray(ap), m)
            for key in ("am_acc", "sum_x", "sum_a", "sum_w"):
                np.testing.assert_array_equal(np.asarray(base[key]),
                                              np.asarray(padded[key]))


def test_k_split_accumulation_is_exact():
    """Summing per-K-tile outputs == one big-K GEMM (coordinator contract)."""
    rng = np.random.default_rng(4)
    w = rng.integers(0, 256, (8, 64)).astype(np.int32)
    a = rng.integers(0, 256, (64, 8)).astype(np.int32)
    for family in ("perforated", "recursive", "truncated"):
        whole = ref.gemm_parts(family, jnp.asarray(w), jnp.asarray(a), 3)
        acc = {k: 0 for k in ("am_acc", "sum_x", "sum_a", "sum_w")}
        for k0 in range(0, 64, 16):
            part = ref.gemm_parts(family, jnp.asarray(w[:, k0:k0 + 16]),
                                  jnp.asarray(a[k0:k0 + 16]), 3)
            for key in acc:
                acc[key] = acc[key] + np.asarray(part[key])
        for key in acc:
            np.testing.assert_array_equal(acc[key], np.asarray(whole[key]))
