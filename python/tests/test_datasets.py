"""Synthetic dataset generator: determinism, balance, learnability signals."""

import numpy as np
import pytest

from compile import datasets


def test_deterministic():
    a, la = datasets.make_split(10, 64, 123)
    b, lb = datasets.make_split(10, 64, 123)
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(la, lb)


def test_different_seeds_differ():
    a, _ = datasets.make_split(10, 16, 1)
    b, _ = datasets.make_split(10, 16, 2)
    assert not np.array_equal(a, b)


@pytest.mark.parametrize("n_classes", [10, 100])
def test_balanced_and_in_range(n_classes):
    imgs, labels = datasets.make_split(n_classes, n_classes * 4, 9)
    counts = np.bincount(labels, minlength=n_classes)
    assert counts.min() == counts.max() == 4
    assert imgs.min() >= 0 and imgs.max() <= 1
    assert imgs.dtype == np.float32


def test_classes_are_visually_distinct():
    """Mean intra-class distance < mean inter-class distance (learnable)."""
    rng = np.random.default_rng(0)
    imgs, labels = datasets.make_split(10, 200, 77)
    flat = imgs.reshape(len(imgs), -1)
    intra, inter = [], []
    for _ in range(300):
        i, j = rng.integers(0, len(imgs), 2)
        d = np.linalg.norm(flat[i] - flat[j])
        (intra if labels[i] == labels[j] else inter).append(d)
    assert np.mean(intra) < np.mean(inter)


def test_shapes_all_defined():
    for s in range(10):
        m = datasets.shape_mask(s, 16, 16, 9)
        assert m.shape == (32, 32)
        assert 0 < m.sum() < 32 * 32  # neither empty nor full


def test_class_spec_bijection_synth100():
    specs = {datasets.class_spec(l, 100)[:2] for l in range(100)}
    assert len(specs) == 100


def test_canonical_splits_disjoint_seeds():
    s = datasets.SPLITS
    for name in ("synth10", "synth100"):
        seeds = [s[name][k][1] for k in ("train", "calib", "test")]
        assert len(set(seeds)) == 3
