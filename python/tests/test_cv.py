"""Statistical properties of the control variate (the paper's §3 claims).

These are the paper's theorems checked empirically on the oracle:
  (i)  E[eps_G*] ~= 0            (mean convolution error nullified, eqs 22/28)
  (ii) Var(eps_G*) << Var(eps_G) (variance reduced, eq 20)
  (iii) C = E[W] minimizes the variance over C (eq 21)
"""

import numpy as np
import pytest

import jax.numpy as jnp

from compile.kernels import ref


def _conv_errors(family, m, w, a_batch, use_cv):
    """eps_G(*) for a batch of activation columns: [n_trials, M]."""
    exact = np.asarray(ref.exact_gemm(jnp.asarray(w), jnp.asarray(a_batch)))
    parts = ref.gemm_parts(family, jnp.asarray(w), jnp.asarray(a_batch), m)
    if use_cv:
        c_q4, c0_q4 = ref.cv_constants(family, jnp.asarray(w), m)
        approx_out = np.asarray(ref.apply_cv(parts, c_q4, c0_q4))
    else:
        approx_out = np.asarray(parts["am_acc"])
    return (exact - approx_out).T  # [N, M]


@pytest.mark.parametrize("family,m", [("perforated", 1), ("perforated", 2),
                                      ("perforated", 3), ("recursive", 3),
                                      ("recursive", 4), ("truncated", 5),
                                      ("truncated", 6), ("truncated", 7)])
def test_cv_nullifies_mean_and_cuts_variance(family, m):
    rng = np.random.default_rng(11)
    k, n = 64, 4000
    # weights concentrated like trained filters (paper Fig 4)
    w = np.clip(rng.normal(128, 20, (4, k)), 0, 255).astype(np.int32)
    a = rng.integers(0, 256, (k, n)).astype(np.int32)
    e_raw = _conv_errors(family, m, w, a, use_cv=False)
    e_cv = _conv_errors(family, m, w, a, use_cv=True)
    raw_mean = np.abs(e_raw.mean(axis=0))
    cv_mean = np.abs(e_cv.mean(axis=0))
    # (i) mean error: CV mean is tiny relative to raw mean (k*mu_AM)
    assert np.all(cv_mean <= 0.05 * raw_mean + 2.0), (cv_mean, raw_mean)
    # (ii) variance strictly reduced
    assert np.all(e_cv.var(axis=0) < e_raw.var(axis=0))


def test_c_equals_mean_w_is_optimal():
    """Perforated: Var over C has its minimum at C = E[W] (eq. 21)."""
    rng = np.random.default_rng(5)
    k, n, m = 48, 3000, 2
    w = np.clip(rng.normal(110, 25, (1, k)), 0, 255).astype(np.int32)
    a = rng.integers(0, 256, (k, n)).astype(np.int64)
    x = a & ((1 << m) - 1)
    eps = (w.astype(np.int64).T * x).sum(axis=0)  # [n]
    c_opt = w.mean()

    def var_with_c(c):
        v = c * x.sum(axis=0)
        return (eps - v).var()

    v_opt = var_with_c(c_opt)
    for dc in (-20, -10, 10, 20):
        assert var_with_c(c_opt + dc) > v_opt


def test_truncated_c0_matches_eq28():
    """Residual mean error without C0 equals 2^-m * sum(What) (eq. 28)."""
    rng = np.random.default_rng(6)
    k, n, m = 32, 20000, 5
    w = rng.integers(0, 256, (1, k)).astype(np.int32)
    a = rng.integers(0, 256, (k, n)).astype(np.int32)
    parts = ref.gemm_parts("truncated", jnp.asarray(w), jnp.asarray(a), m)
    exact = np.asarray(ref.exact_gemm(jnp.asarray(w), jnp.asarray(a)))
    c_q4, _ = ref.cv_constants("truncated", jnp.asarray(w), m)
    # apply V with C only (C0 = 0):
    v = (np.asarray(c_q4)[:, None] * np.asarray(parts["sum_x"])[None, :] + 8) >> 4
    resid = (exact - (np.asarray(parts["am_acc"]) + v)).mean()
    what = np.asarray(ref.cv_constants("truncated", jnp.asarray(w), m)[0])  # C in Q4
    from compile.kernels import approx
    what_sum = float(np.asarray(approx.w_hat_q1(jnp.asarray(w), jnp.int32(m))).sum()) / 2
    expect = what_sum / (1 << m)
    assert abs(resid - expect) < max(0.15 * expect, 1.5), (resid, expect)


def test_exact_family_cv_is_noop():
    rng = np.random.default_rng(7)
    w = rng.integers(0, 256, (4, 16)).astype(np.int32)
    a = rng.integers(0, 256, (16, 8)).astype(np.int32)
    parts = ref.gemm_parts("exact", jnp.asarray(w), jnp.asarray(a), 0)
    c, c0 = ref.cv_constants("exact", jnp.asarray(w), 0)
    out = np.asarray(ref.apply_cv(parts, c, c0))
    np.testing.assert_array_equal(out, np.asarray(ref.exact_gemm(
        jnp.asarray(w), jnp.asarray(a))))
