"""Deterministic synthetic image datasets: synth10 / synth100.

Stand-in for Cifar-10 / Cifar-100 (no dataset downloads in this environment —
see DESIGN.md §2). 32x32x3 class-conditional images: a textured background
plus a geometric figure whose (shape, hue) defines the class. synth10 uses 10
shapes at a fixed hue family; synth100 crosses 10 shapes x 10 hues. Position,
scale, rotation-ish jitter, occlusion noise and sensor noise make the task
non-trivial, so trained networks develop natural, non-degenerate weight and
activation distributions — which is what the paper's error model feeds on.

Generation is seeded and identical across runs; the exported .cvd binaries
(export.py) are the single source of truth consumed by the rust engine.
"""

from __future__ import annotations

import numpy as np

H = W = 32
C = 3
N_SHAPES = 10


def _coords(cx, cy, r):
    yy, xx = np.mgrid[0:H, 0:W].astype(np.float32)
    return (yy - cy) / r, (xx - cx) / r


def shape_mask(shape_id: int, cx: float, cy: float, r: float) -> np.ndarray:
    """[H,W] float mask in [0,1] for one of the 10 figure classes."""
    v, u = _coords(cx, cy, r)
    d = np.sqrt(u * u + v * v)
    if shape_id == 0:  # disc
        m = d < 1.0
    elif shape_id == 1:  # square
        m = np.maximum(np.abs(u), np.abs(v)) < 0.9
    elif shape_id == 2:  # triangle
        m = (v > -0.8) & (np.abs(u) < (0.9 - v) * 0.6)
    elif shape_id == 3:  # ring
        m = (d < 1.0) & (d > 0.55)
    elif shape_id == 4:  # cross
        m = (np.abs(u) < 0.35) | (np.abs(v) < 0.35)
        m &= np.maximum(np.abs(u), np.abs(v)) < 1.0
    elif shape_id == 5:  # diamond
        m = (np.abs(u) + np.abs(v)) < 1.1
    elif shape_id == 6:  # horizontal stripes
        m = (np.sin(v * 3 * np.pi) > 0) & (d < 1.1)
    elif shape_id == 7:  # vertical stripes
        m = (np.sin(u * 3 * np.pi) > 0) & (d < 1.1)
    elif shape_id == 8:  # checkerboard
        m = ((np.sin(u * 2.5 * np.pi) * np.sin(v * 2.5 * np.pi)) > 0) & (d < 1.1)
    elif shape_id == 9:  # dot grid
        m = ((np.sin(u * 4 * np.pi) > 0.3) & (np.sin(v * 4 * np.pi) > 0.3)) & (d < 1.1)
    else:
        raise ValueError(shape_id)
    return m.astype(np.float32)


def _hue_rgb(hue_id: int, n_hues: int) -> np.ndarray:
    """Well-separated RGB triplet for hue class `hue_id`."""
    t = hue_id / n_hues * 2 * np.pi
    return 0.5 + 0.45 * np.array(
        [np.cos(t), np.cos(t - 2 * np.pi / 3), np.cos(t + 2 * np.pi / 3)],
        np.float32,
    )


def class_spec(label: int, n_classes: int) -> tuple[int, int, int]:
    """label -> (shape_id, hue_id, n_hues)."""
    if n_classes == 10:
        return label % N_SHAPES, label // N_SHAPES, 1
    if n_classes == 100:
        return label % N_SHAPES, label // N_SHAPES, 10
    raise ValueError(n_classes)


def render(label: int, n_classes: int, rng: np.random.Generator) -> np.ndarray:
    """One [H,W,C] float32 image in [0,1] for `label`."""
    shape_id, hue_id, n_hues = class_spec(label, n_classes)
    fg = _hue_rgb(hue_id, max(n_hues, 3))
    # Background: low-frequency noise field with a random tint.
    bg_tint = rng.uniform(0.1, 0.9, 3).astype(np.float32)
    coarse = rng.uniform(0, 1, (4, 4, 1)).astype(np.float32)
    bg = np.kron(coarse, np.ones((8, 8, 1), np.float32)) * 0.4 + 0.3
    img = bg * bg_tint
    # Figure with jittered placement/size and brightness.
    cx = W / 2 + rng.uniform(-5, 5)
    cy = H / 2 + rng.uniform(-5, 5)
    r = rng.uniform(7.5, 11.5)
    mask = shape_mask(shape_id, cx, cy, r)[..., None]
    glow = rng.uniform(0.75, 1.15)
    img = img * (1 - mask) + mask * np.clip(fg * glow, 0, 1)
    # Occlusion speckle + sensor noise.
    speck = rng.uniform(0, 1, (H, W, 1)) < 0.02
    img = np.where(speck, rng.uniform(0, 1, (H, W, C)).astype(np.float32), img)
    img = img + rng.normal(0, 0.03, (H, W, C)).astype(np.float32)
    return np.clip(img, 0, 1).astype(np.float32)


def make_split(n_classes: int, n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Balanced split: images [n,H,W,C] f32, labels [n] i32."""
    rng = np.random.default_rng(seed)
    labels = np.arange(n) % n_classes
    rng.shuffle(labels)
    imgs = np.stack([render(int(l), n_classes, rng) for l in labels])
    return imgs, labels.astype(np.int32)


# Canonical split seeds — rust-side tests rely on these being stable.
SPLITS = {
    "synth10": dict(n_classes=10, train=(4000, 101), calib=(256, 103), test=(1000, 102)),
    "synth100": dict(n_classes=100, train=(6000, 201), calib=(256, 203), test=(1000, 202)),
}


def load(name: str, split: str) -> tuple[np.ndarray, np.ndarray, int]:
    spec = SPLITS[name]
    n, seed = spec[split]
    imgs, labels = make_split(spec["n_classes"], n, seed)
    return imgs, labels, spec["n_classes"]
