"""AOT build driver: HLO artifacts + trained/quantized models + datasets + goldens.

Run from python/:  python -m compile.aot --out-dir ../artifacts

Emits HLO **text** (not serialized protos): the rust `xla` crate links
xla_extension 0.5.1 which rejects jax>=0.5's 64-bit instruction ids; the text
parser reassigns ids (see /opt/xla-example/README.md). Everything here is
build-time only — python never runs on the request path.

Outputs:
  artifacts/hlo/gemm_<family>_<pallas|fast>.hlo.txt   (8 tile-GEMM executables)
  artifacts/data/<ds>_{test,calib}.cvd
  artifacts/models/<net>_<ds>.cvm                     (12 quantized models)
  artifacts/golden/*.gv                               (integration vectors)
  artifacts/ckpt/*.pkl                                (float training cache)
  artifacts/BUILD_OK                                  (make stamp)
"""

from __future__ import annotations

import argparse
import functools
import time
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import datasets, export, model, quant, train
from .kernels import approx, gemm

NETS = ["mininet", "vggnet11", "resnet8", "resnet14", "inceptionnet", "shufflenet"]
DATASETS = ["synth10", "synth100"]
# Representative (family, m) points for golden vectors — one per family plus
# exact, both with and without V.
GOLDEN_POINTS = [("exact", 0, False), ("perforated", 2, False),
                 ("perforated", 2, True), ("recursive", 3, True),
                 ("truncated", 6, True), ("truncated", 6, False)]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def build_hlo(out: Path, log=print) -> None:
    hlo_dir = out / "hlo"
    hlo_dir.mkdir(parents=True, exist_ok=True)
    m_spec = jax.ShapeDtypeStruct((1,), jnp.int32)
    w_spec = jax.ShapeDtypeStruct((gemm.TM, gemm.TK), jnp.int32)
    a_spec = jax.ShapeDtypeStruct((gemm.TK, gemm.TN), jnp.int32)
    for family in approx.FAMILIES:
        for variant, fn in (("pallas", gemm.pallas_tile_gemm),
                            ("fast", gemm.jnp_tile_gemm)):
            path = hlo_dir / f"gemm_{family}_{variant}.hlo.txt"
            lowered = jax.jit(functools.partial(fn, family)).lower(
                m_spec, w_spec, a_spec)
            text = to_hlo_text(lowered)
            path.write_text(text)
            log(f"  hlo: {path.name} ({len(text) // 1024} KiB)")


def build_datasets(out: Path, log=print) -> None:
    data_dir = out / "data"
    data_dir.mkdir(parents=True, exist_ok=True)
    for ds in DATASETS:
        for split in ("test", "calib"):
            path = data_dir / f"{ds}_{split}.cvd"
            if path.exists():
                continue
            imgs, labels, _ = datasets.load(ds, split)
            scale, zp = quant.INPUT_SCALE, 0
            imgs_q = quant.quantize(imgs, scale, zp)
            export.write_dataset(path, imgs_q, labels, scale, zp)
            log(f"  data: {path.name} n={len(labels)}")


def build_models(out: Path, epochs: int, log=print) -> dict:
    """Train (cached), quantize, export; returns {model_key: QuantModel}."""
    models_dir = out / "models"
    models_dir.mkdir(parents=True, exist_ok=True)
    ckpt_dir = out / "ckpt"
    qms = {}
    for ds in DATASETS:
        calib_imgs, _, n_classes = datasets.load(ds, "calib")
        for net in NETS:
            key = f"{net}_{ds}"
            nodes, params, facc = train.train_or_load(net, ds, ckpt_dir,
                                                      epochs=epochs, log=log)
            qm = model.quantize_model(key, nodes, params, calib_imgs)
            export.write_model(models_dir / f"{key}.cvm", qm, n_classes)
            qms[key] = qm
            log(f"  model: {key}.cvm float_acc={facc:.3f}")
    return qms


def build_golden(out: Path, qms: dict, log=print) -> None:
    """Golden logits from the numpy quantized reference for rust cross-checks."""
    gold_dir = out / "golden"
    gold_dir.mkdir(parents=True, exist_ok=True)
    # Two models exercise every op: shufflenet (groups/shuffle/add) and
    # inceptionnet (concat); plus resnet8 for the e2e example.
    for key in ("resnet8_synth10", "shufflenet_synth10", "inceptionnet_synth100"):
        qm = qms[key]
        ds = key.rsplit("_", 1)[1]
        imgs, _, _ = datasets.load(ds, "test")
        for img_idx in (0, 7):
            img_q = quant.quantize(imgs[img_idx], quant.INPUT_SCALE, 0)
            for family, m, use_cv in GOLDEN_POINTS:
                logits = qm.forward(img_q, family, m, use_cv)
                name = f"{key}_i{img_idx}_{family}{m}_{'cv' if use_cv else 'raw'}.gv"
                export.write_golden(gold_dir / name, key, family, m, use_cv,
                                    img_idx, logits)
        log(f"  golden: {key} ({len(GOLDEN_POINTS) * 2} vectors)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--epochs", type=int, default=10)
    ap.add_argument("--hlo-only", action="store_true",
                    help="only regenerate the HLO artifacts")
    args = ap.parse_args()
    out = Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)
    t0 = time.time()
    print("[aot] lowering HLO artifacts")
    build_hlo(out)
    if not args.hlo_only:
        print("[aot] generating datasets")
        build_datasets(out)
        print("[aot] training + quantizing models")
        qms = build_models(out, args.epochs)
        print("[aot] golden vectors")
        build_golden(out, qms)
    (out / "BUILD_OK").write_text(f"built in {time.time() - t0:.0f}s\n")
    print(f"[aot] done in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
