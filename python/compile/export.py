"""Binary export of quantized models (.cvm), datasets (.cvd) and golden vectors.

Little-endian throughout. rust/src/nn/loader.rs and rust/src/datasets/ are the
consuming parsers — keep the three in lockstep.

.cvd (dataset):
  magic  "CVD1"
  u32 n, u32 h, u32 w, u32 c
  f32 scale, i32 zero_point          # input quantization of the images
  u8  images[n*h*w*c]                # already quantized (HWC, row-major)
  u16 labels[n]

.cvm (model):
  magic  "CVM1"
  u16 name_len, utf8 name
  u16 n_classes
  u32 n_nodes
  per node:
    u8 op      (0 input, 1 conv, 2 maxpool, 3 gap, 4 dense, 5 add, 6 concat,
                7 shuffle)
    u8 relu
    u16 n_inputs, u32 inputs[n_inputs]
    u32 out_h, u32 out_w, u32 out_c
    f32 out_scale, i32 out_zp
    op params:
      conv : u16 cout, u8 k, u8 stride, u8 pad, u8 _rsv, u16 groups,
             f32 s_w, i32 zp_w,
             u8 w_q[cout * k*k*(cin/groups)]   # row-major [cout][ky][kx][cin/g]
             i32 b_q[cout]
      dense: u32 nout, u32 nin, f32 s_w, i32 zp_w,
             u8 w_q[nout*nin], i32 b_q[nout]
      shuffle: u16 groups
      others: none

golden vector (.gv): exact/approx forward outputs for integration tests:
  magic "CVG1", u16 name_len + name (model file stem),
  u8 family (0 exact,1 perforated,2 recursive,3 truncated), u8 m, u8 use_cv,
  u32 img_index, u32 n_logits, f64 logits[n_logits]
"""

from __future__ import annotations

import struct
from pathlib import Path

import numpy as np

from .model import QuantModel

OPCODE = {"input": 0, "conv": 1, "maxpool": 2, "gap": 3, "dense": 4,
          "add": 5, "concat": 6, "shuffle": 7}
FAMCODE = {"exact": 0, "perforated": 1, "recursive": 2, "truncated": 3}


def write_dataset(path: Path, imgs_q: np.ndarray, labels: np.ndarray,
                  scale: float, zp: int) -> None:
    n, h, w, c = imgs_q.shape
    assert imgs_q.dtype == np.uint8
    with open(path, "wb") as f:
        f.write(b"CVD1")
        f.write(struct.pack("<IIII", n, h, w, c))
        f.write(struct.pack("<fi", scale, zp))
        f.write(imgs_q.tobytes())
        f.write(labels.astype(np.uint16).tobytes())


def write_model(path: Path, qm: QuantModel, n_classes: int) -> None:
    with open(path, "wb") as f:
        f.write(b"CVM1")
        name = qm.name.encode()
        f.write(struct.pack("<H", len(name)))
        f.write(name)
        f.write(struct.pack("<H", n_classes))
        f.write(struct.pack("<I", len(qm.nodes)))
        for i, n in enumerate(qm.nodes):
            oh, ow, oc = qm.shapes[i]
            s, zp = qm.out_q[i]
            f.write(struct.pack("<BB", OPCODE[n.op], int(n.relu)))
            f.write(struct.pack("<H", len(n.inputs)))
            for j in n.inputs:
                f.write(struct.pack("<I", j))
            f.write(struct.pack("<IIIfi", oh, ow, oc, s, zp))
            if n.op == "conv":
                wrec = qm.weights[i]
                f.write(struct.pack("<HBBBBH", n.cout, n.k, n.stride, n.pad,
                                    0, n.groups))
                f.write(struct.pack("<fi", wrec["s_w"], wrec["zp_w"]))
                f.write(wrec["w_q"].astype(np.uint8).tobytes())
                f.write(wrec["b_q"].astype(np.int32).tobytes())
            elif n.op == "dense":
                wrec = qm.weights[i]
                nout, nin = wrec["w_q"].shape
                f.write(struct.pack("<II", nout, nin))
                f.write(struct.pack("<fi", wrec["s_w"], wrec["zp_w"]))
                f.write(wrec["w_q"].astype(np.uint8).tobytes())
                f.write(wrec["b_q"].astype(np.int32).tobytes())
            elif n.op == "shuffle":
                f.write(struct.pack("<H", n.groups))


def write_golden(path: Path, model_name: str, family: str, m: int,
                 use_cv: bool, img_index: int, logits: np.ndarray) -> None:
    with open(path, "wb") as f:
        f.write(b"CVG1")
        name = model_name.encode()
        f.write(struct.pack("<H", len(name)))
        f.write(name)
        f.write(struct.pack("<BBB", FAMCODE[family], m, int(use_cv)))
        f.write(struct.pack("<II", img_index, logits.shape[0]))
        f.write(logits.astype(np.float64).tobytes())
