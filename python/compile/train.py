"""Build-time training of the six nets on synth10/synth100 (hand-rolled Adam).

No optax in this environment — Adam is ~20 lines. Training is float32, jit'd,
single CPU core; the nets are sized so each (net, dataset) pair trains in a
couple of minutes. Checkpoints are cached under artifacts/ckpt/ as .npz so
`make artifacts` is incremental.
"""

from __future__ import annotations

import pickle
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from . import datasets, model, nets


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree_util.tree_map(lambda m: m / (1 - b1 ** t), m)
    vh = jax.tree_util.tree_map(lambda v: v / (1 - b2 ** t), v)
    new = jax.tree_util.tree_map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mh, vh)
    return new, {"m": m, "v": v, "t": t}


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -logp[jnp.arange(labels.shape[0]), labels].mean()


def train_net(net_name: str, ds_name: str, epochs: int = 10, batch: int = 128,
              lr: float = 2e-3, seed: int = 7, log=print):
    """Train one net; returns (nodes, params, float_test_accuracy)."""
    xs, ys, n_classes = datasets.load(ds_name, "train")
    xt, yt, _ = datasets.load(ds_name, "test")
    nodes = nets.NETS[net_name](n_classes)
    params = model.init_params(nodes, seed)

    def loss_fn(p, x, y):
        return cross_entropy(model.float_forward(nodes, p, x), y)

    @jax.jit
    def step(p, st, x, y, lr_now):
        loss, grads = jax.value_and_grad(loss_fn)(p, x, y)
        p, st = adam_update(p, grads, st, lr_now)
        return p, st, loss

    @jax.jit
    def accuracy(p, x, y):
        return (model.float_forward(nodes, p, x).argmax(-1) == y).mean()

    st = adam_init(params)
    rng = np.random.default_rng(seed)
    n = xs.shape[0]
    t0 = time.time()
    for ep in range(epochs):
        order = rng.permutation(n)
        lr_now = lr * (0.5 ** (ep / max(epochs - 1, 1) * 2))  # ~4x decay
        losses = []
        for i in range(0, n - batch + 1, batch):
            idx = order[i:i + batch]
            params, st, loss = step(params, st, jnp.asarray(xs[idx]),
                                    jnp.asarray(ys[idx]), lr_now)
            losses.append(float(loss))
        if ep == epochs - 1 or ep % 3 == 0:
            acc = float(accuracy(params, jnp.asarray(xt[:500]), jnp.asarray(yt[:500])))
            log(f"  [{net_name}/{ds_name}] epoch {ep + 1}/{epochs} "
                f"loss={np.mean(losses):.3f} test_acc={acc:.3f} "
                f"({time.time() - t0:.0f}s)")
    acc = float(accuracy(params, jnp.asarray(xt), jnp.asarray(yt)))
    return nodes, params, acc


def train_or_load(net_name: str, ds_name: str, ckpt_dir: Path, **kw):
    """Cached training: artifacts/ckpt/<net>_<ds>.pkl."""
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    path = ckpt_dir / f"{net_name}_{ds_name}.pkl"
    if path.exists():
        with open(path, "rb") as f:
            blob = pickle.load(f)
        n_classes = datasets.SPLITS[ds_name]["n_classes"]
        nodes = nets.NETS[net_name](n_classes)
        params = {int(k): {"w": jnp.asarray(v["w"]), "b": jnp.asarray(v["b"])}
                  for k, v in blob["params"].items()}
        return nodes, params, blob["acc"]
    nodes, params, acc = train_net(net_name, ds_name, **kw)
    blob = {"params": {k: {"w": np.asarray(v["w"]), "b": np.asarray(v["b"])}
                       for k, v in params.items()},
            "acc": acc}
    with open(path, "wb") as f:
        pickle.dump(blob, f)
    return nodes, params, acc
