"""Arithmetic identities for the paper's approximate 8x8 multipliers.

All three families (perforated [22], recursive [23,24], truncated [17-19])
admit *exact* integer identities of the form AM(W, A) = W*A - eps(W, A),
where eps is the multiplication error of eq. (3)/(6)/(8) in the paper.
Operands are uint8 values carried in i32 tensors (bit ops + products stay
well inside i32: max error term < 2^16, max accumulator growth is bounded
by the K dimension which the coordinator tiles).

These functions are the single source of truth shared by:
  - ref.py           (pure-jnp oracle used by pytest/hypothesis),
  - gemm.py          (Pallas kernels — same expressions inside the kernel),
  - the rust `approx` module re-implements them and cross-checks against a
    partial-product bit-level model for all 2^16 operand pairs.

`m` is a traced scalar (i32) so one lowered artifact serves every
approximation level of a family.
"""

from __future__ import annotations

import jax.numpy as jnp

# Highest approximation knob the paper evaluates (truncated m=7 on 8x8).
MAX_M = 7

FAMILIES = ("exact", "perforated", "recursive", "truncated")


def _mask(m):
    """2^m - 1 as an i32 scalar (m may be traced)."""
    return jnp.left_shift(jnp.int32(1), m.astype(jnp.int32)) - 1


def err_perforated(w, a, m):
    """eps = W * (A mod 2^m)  — eq. (3), s=0."""
    return w * (a & _mask(m))


def err_recursive(w, a, m):
    """eps = W_L * A_L = (W mod 2^m)(A mod 2^m) — eq. (6)."""
    return (w & _mask(m)) * (a & _mask(m))


def err_truncated(w, a, m):
    """eps = sum_{i<m} (W mod 2^{m-i}) * a_i * 2^i — eq. (8).

    Static unroll over i in [0, MAX_M); terms with i >= m are masked out so
    `m` can stay a traced runtime scalar.
    """
    m = m.astype(jnp.int32)
    eps = jnp.zeros(jnp.broadcast_shapes(jnp.shape(w), jnp.shape(a)), jnp.int32)
    for i in range(MAX_M):
        sh = jnp.maximum(m - i, 0)  # clamp: negative shifts are UB
        term = (w & _mask(sh)) * ((a >> i) & 1) << i
        eps = eps + jnp.where(i < m, term, 0)
    return eps


_ERR = {
    "perforated": err_perforated,
    "recursive": err_recursive,
    "truncated": err_truncated,
}


def err(family, w, a, m):
    """Multiplication error eps(W, A) for `family` (0 for exact)."""
    if family == "exact":
        return jnp.zeros(jnp.broadcast_shapes(jnp.shape(w), jnp.shape(a)), jnp.int32)
    return _ERR[family](w, a, m)


def am(family, w, a, m):
    """Approximate product AM(W, A) = W*A - eps(W, A)."""
    return w * a - err(family, w, a, m)


def xvar(family, a, m):
    """Control-variate input term x_j of eq. (18)/(25)/(29).

    perforated / recursive: x_j = A mod 2^m (m-bit value)
    truncated:              x_j = OR(A[m-1:0]) in {0, 1}
    exact:                  0 (V is unused)
    """
    if family == "exact":
        return jnp.zeros(jnp.shape(a), jnp.int32)
    low = a & _mask(m)
    if family == "truncated":
        return (low != 0).astype(jnp.int32)
    return low


def w_hat_q1(w, m):
    """2 * What_j  (eq. 24, scaled by 2 so it stays integral).

    What_j = 1/2 sum_{i<m} (W mod 2^{m-i}) 2^i is the average truncation
    error of AM_T(W, .) over uniform A. The hardware carries it in fixed
    point; we keep one fractional bit (Q.1).
    """
    m = m.astype(jnp.int32)
    acc = jnp.zeros(jnp.shape(w), jnp.int32)
    for i in range(MAX_M):
        sh = jnp.maximum(m - i, 0)
        acc = acc + jnp.where(i < m, (w & _mask(sh)) << i, 0)
    return acc  # = 2 * What
