"""L1 Pallas kernels: approximate quantized tile-GEMM with fused control-variate sums.

One kernel per multiplier family. Each computes, for a fixed-shape tile
W[TM,TK] x A[TK,TN] (uint8 values in i32):

    am_acc[f,p] = sum_k AM(W[f,k], A[k,p])      (MAC* accumulator chain)
    sum_x[p]    = sum_k x(A[k,p])               (MAC* sumX chain, fused)
    sum_a[p]    = sum_k A[k,p]                  (zero-point correction)
    sum_w[f]    = sum_k W[f,k]                  (zero-point correction)

The approximation level m is a runtime scalar, so ONE artifact per family
serves every m — the coordinator never recompiles to change m.

TPU mapping (DESIGN.md §8): instead of emulating the systolic array cell by
cell, the error identities AM = W*A - eps turn every family into 1-2 extra
*matmuls over masked operands* (truncated: up to MAX_M rank-preserving
bit-plane matmuls) — exactly what the MXU runs as int8 dots with i32
accumulation. The sumX reduction rides the same A tile while it is resident
in VMEM, mirroring the paper's observation that the sumX adder is off the
critical path. interpret=True everywhere: CPU PJRT cannot execute Mosaic
custom-calls; real-TPU perf is estimated in DESIGN.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import approx

# Fixed artifact tile shape (the "systolic array unroll"). K is the reduction;
# the rust coordinator accumulates across K tiles (exact: all outputs are
# k-sums) and pads with zeros (exact: eps(w,0)=eps(0,a)=0 and x(0)=0).
TM, TK, TN = 64, 64, 256

# VMEM footprint estimate for the default tile (i32 everywhere):
#   W 64x64 + A 64x256 + am_acc 64x256 + vectors  ~= 64*64*4 + 2*64*256*4
#   + (256+256+64)*4 ~= 16 KiB + 128 KiB + 2.3 KiB ~= 147 KiB << 16 MiB VMEM.
# Truncated adds MAX_M masked operand temporaries (transient, fused on MXU).


def _mask(m):
    return jnp.left_shift(jnp.int32(1), m) - 1


def _dot(x, y):
    """i32 matmul on the MXU path (int8 operands, 32-bit accumulate on TPU)."""
    return jax.lax.dot_general(
        x, y, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )


def _err_acc(family, w, a, m):
    """sum_k eps(W[f,k],A[k,p]) as masked-operand matmuls (see module doc)."""
    if family == "exact":
        return jnp.zeros((w.shape[0], a.shape[1]), jnp.int32)
    if family == "perforated":
        return _dot(w, a & _mask(m))
    if family == "recursive":
        return _dot(w & _mask(m), a & _mask(m))
    if family == "truncated":
        acc = jnp.zeros((w.shape[0], a.shape[1]), jnp.int32)
        for i in range(approx.MAX_M):
            sh = jnp.maximum(m - i, 0)
            bitplane = (a >> i) & 1
            term = _dot(w & _mask(sh), bitplane) << i
            acc = acc + jnp.where(i < m, term, 0)
        return acc
    raise ValueError(family)


def _sum_x(family, a, m):
    """sum_k x(A[k,p]) over the K axis of the resident A tile."""
    if family == "exact":
        return jnp.zeros((a.shape[1],), jnp.int32)
    low = a & _mask(m)
    if family == "truncated":
        low = (low != 0).astype(jnp.int32)
    return low.sum(axis=0, dtype=jnp.int32)


def _tile_kernel(family, m_ref, w_ref, a_ref, am_ref, sx_ref, sa_ref, sw_ref):
    m = m_ref[0]
    w = w_ref[...]
    a = a_ref[...]
    am_ref[...] = _dot(w, a) - _err_acc(family, w, a, m)
    sx_ref[...] = _sum_x(family, a, m)
    sa_ref[...] = a.sum(axis=0, dtype=jnp.int32)
    sw_ref[...] = w.sum(axis=1, dtype=jnp.int32)


@functools.partial(jax.jit, static_argnums=0)
def pallas_tile_gemm(family, m, w, a):
    """Run the family's Pallas tile kernel. Shapes: m[1] i32, w[TM,TK], a[TK,TN].

    Returns (am_acc[TM,TN], sum_x[TN], sum_a[TN], sum_w[TM]), all i32.
    """
    tm, tk = w.shape
    tk2, tn = a.shape
    assert tk == tk2, (w.shape, a.shape)
    return pl.pallas_call(
        functools.partial(_tile_kernel, family),
        out_shape=(
            jax.ShapeDtypeStruct((tm, tn), jnp.int32),
            jax.ShapeDtypeStruct((tn,), jnp.int32),
            jax.ShapeDtypeStruct((tn,), jnp.int32),
            jax.ShapeDtypeStruct((tm,), jnp.int32),
        ),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(m.astype(jnp.int32), w.astype(jnp.int32), a.astype(jnp.int32))


@functools.partial(jax.jit, static_argnums=0)
def jnp_tile_gemm(family, m, w, a):
    """Identity-based fast path (no Pallas): same outputs, XLA-fused matmuls.

    Kept as a separate artifact for the serving fast path; the ablation bench
    compares it against the Pallas lowering (EXPERIMENTS.md §Perf).
    """
    m_s = m.astype(jnp.int32)[0]
    w = w.astype(jnp.int32)
    a = a.astype(jnp.int32)
    am_acc = _dot(w, a) - _err_acc(family, w, a, m_s)
    # Keep `m` alive for the exact family too: jax would otherwise DCE the
    # parameter and the AOT artifact would expect 2 buffers instead of 3.
    am_acc = am_acc + (m_s & 0)
    return am_acc, _sum_x(family, a, m_s), a.sum(0, dtype=jnp.int32), w.sum(
        1, dtype=jnp.int32
    )
