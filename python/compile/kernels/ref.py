"""Pure-jnp oracle for the approximate tile-GEMM + control-variate kernels.

This is the CORE correctness reference: the Pallas kernels in gemm.py and the
rust GEMM engines must agree bit-exactly with these functions. Everything is
i32; operands are uint8 values.

Conv-as-GEMM orientation (matches the systolic array in the paper, Fig 5/6):
    G[f, p] = sum_k W[f, k] * A[k, p]
f indexes filters (rows of W), p output positions (columns of A), k the
k*k*Cin reduction. The control variate V[f, p] = C_f * sumX[p] is rank-1:
sumX depends only on the activation column, C only on the filter row.
"""

from __future__ import annotations

import jax.numpy as jnp

from . import approx


def gemm_parts(family, w, a, m):
    """All accumulator outputs the hardware array produces for one tile.

    Args:
      family: one of approx.FAMILIES.
      w: [M, K] i32 (uint8 values) — weights, filter-major.
      a: [K, N] i32 (uint8 values) — im2col activations.
      m: scalar i32 — approximation level (ignored for exact).

    Returns dict of:
      am_acc: [M, N] i32 — sum_k AM(W[f,k], A[k,p])  (the MAC* chain output)
      sum_x:  [N] i32    — sum_k x(A[k,p])           (the MAC* sumX chain)
      sum_a:  [N] i32    — sum_k A[k,p]              (zero-point row sum)
      sum_w:  [M] i32    — sum_k W[f,k]              (zero-point col sum)
    """
    m = jnp.asarray(m, jnp.int32)
    prod = approx.am(family, w[:, None, :], a.T[None, :, :], m)  # [M, N, K]
    am_acc = prod.sum(axis=2, dtype=jnp.int32)
    sum_x = approx.xvar(family, a, m).sum(axis=0, dtype=jnp.int32)
    sum_a = a.sum(axis=0, dtype=jnp.int32)
    sum_w = w.sum(axis=1, dtype=jnp.int32)
    return {"am_acc": am_acc, "sum_x": sum_x, "sum_a": sum_a, "sum_w": sum_w}


def cv_constants(family, w, m, k_valid=None):
    """Per-filter control-variate constants (C and C0 in Q.4 fixed point).

    perforated: C = E[W_j]            (eq. 21), C0 = 0
    recursive:  C = E[W_j mod 2^m]    (eq. 32), C0 = 0
    truncated:  C = E[What_j]         (eq. 26), C0 = 2^-m sum_j What_j (eq. 28)

    Args:
      w: [M, K] i32 weights (uint8 values).
      k_valid: effective filter size k (defaults to K). When the coordinator
        zero-pads K, padding contributes 0 to every sum, but the *averages*
        must divide by the true k — pass it.

    Returns (c_q4 [M] i32, c0_q4 [M] i32), both scaled by 16 (Q.4).
    """
    m = jnp.asarray(m, jnp.int32)
    k = jnp.asarray(w.shape[1] if k_valid is None else k_valid, jnp.int32)
    if family == "exact":
        z = jnp.zeros(w.shape[0], jnp.int32)
        return z, z
    if family == "perforated":
        num = w.sum(axis=1, dtype=jnp.int32)  # sum_j W_j
    elif family == "recursive":
        mask = jnp.left_shift(jnp.int32(1), m) - 1
        num = (w & mask).sum(axis=1, dtype=jnp.int32)
    elif family == "truncated":
        num = approx.w_hat_q1(w, m).sum(axis=1, dtype=jnp.int32)  # 2*sum What
    else:
        raise ValueError(family)
    # C = num / k (truncated: num / 2k); round-to-nearest in Q.4.
    den = k * (2 if family == "truncated" else 1)
    c_q4 = (num * 16 + den // 2) // den
    if family == "truncated":
        # C0 = 2^-m sum What = num / 2^(m+1); in Q.4: num * 16 / 2^(m+1).
        sh = jnp.left_shift(jnp.int32(1), m + 1)
        c0_q4 = (num * 16 + sh // 2) // sh
    else:
        c0_q4 = jnp.zeros(w.shape[0], jnp.int32)
    return c_q4, c0_q4


def apply_cv(parts, c_q4, c0_q4):
    """MAC+ epilogue: G*[f,p] = am_acc[f,p] + round((C_f*sumX[p] + C0_f)/16).

    Returns the V-corrected hardware accumulator (still excludes zero-point
    terms and bias — the coordinator owns those).
    """
    v_q4 = c_q4[:, None] * parts["sum_x"][None, :] + c0_q4[:, None]
    v = (v_q4 + 8) >> 4  # round-to-nearest in Q.4 (ties up)
    return parts["am_acc"] + v


def exact_gemm(w, a):
    """Plain exact i32 GEMM reference."""
    return (w.astype(jnp.int32) @ a.astype(jnp.int32)).astype(jnp.int32)
