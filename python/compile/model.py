"""L2 model: float (training) and quantized (reference) forward passes.

Float path: jax, NHWC, used only at build time to train the six nets.
Quantized path: numpy, bit-exact mirror of the rust `nn` engine — every
rounding rule here is replicated in rust/src/nn/ and checked by golden-vector
tests. The approximate-multiplier families enter ONLY in conv/dense (the ops
the paper's MAC array executes); everything else is exact integer arithmetic.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from . import quant
from .nets import Node

# ---------------------------------------------------------------------------
# Shape inference
# ---------------------------------------------------------------------------


def infer_shapes(nodes: list[Node], in_shape=(32, 32, 3)) -> list[tuple[int, int, int]]:
    """Per-node output shape (h, w, c); dense -> (1, 1, nout)."""
    shapes: list[tuple[int, int, int]] = []
    for n in nodes:
        if n.op == "input":
            shapes.append(in_shape)
        elif n.op == "conv":
            h, w, _ = shapes[n.inputs[0]]
            oh = (h + 2 * n.pad - n.k) // n.stride + 1
            ow = (w + 2 * n.pad - n.k) // n.stride + 1
            shapes.append((oh, ow, n.cout))
        elif n.op == "maxpool":
            h, w, c = shapes[n.inputs[0]]
            shapes.append((h // 2, w // 2, c))
        elif n.op == "gap":
            _, _, c = shapes[n.inputs[0]]
            shapes.append((1, 1, c))
        elif n.op == "dense":
            shapes.append((1, 1, n.nout))
        elif n.op == "add":
            shapes.append(shapes[n.inputs[0]])
        elif n.op == "concat":
            h, w, _ = shapes[n.inputs[0]]
            shapes.append((h, w, sum(shapes[i][2] for i in n.inputs)))
        elif n.op == "shuffle":
            shapes.append(shapes[n.inputs[0]])
        else:
            raise ValueError(n.op)
    return shapes


# ---------------------------------------------------------------------------
# Float path (build-time training + calibration)
# ---------------------------------------------------------------------------


def init_params(nodes: list[Node], seed: int, in_shape=(32, 32, 3)):
    """He-init conv/dense weights. Conv weights jax-layout [k,k,cin/g,cout]."""
    shapes = infer_shapes(nodes, in_shape)
    rng = np.random.default_rng(seed)
    params = {}
    for i, n in enumerate(nodes):
        if n.op == "conv":
            cin = shapes[n.inputs[0]][2] // n.groups
            fan_in = n.k * n.k * cin
            w = rng.normal(0, np.sqrt(2.0 / fan_in), (n.k, n.k, cin, n.cout))
            params[i] = {"w": jnp.asarray(w, jnp.float32),
                         "b": jnp.zeros((n.cout,), jnp.float32)}
        elif n.op == "dense":
            nin = int(np.prod(shapes[n.inputs[0]]))
            w = rng.normal(0, np.sqrt(2.0 / nin), (nin, n.nout))
            params[i] = {"w": jnp.asarray(w, jnp.float32),
                         "b": jnp.zeros((n.nout,), jnp.float32)}
    return params


def float_forward_all(nodes, params, x):
    """Float forward on an NHWC batch returning every node's output."""
    outs = []
    for i, n in enumerate(nodes):
        if n.op == "input":
            y = x
        elif n.op == "conv":
            y = jax.lax.conv_general_dilated(
                outs[n.inputs[0]], params[i]["w"], (n.stride, n.stride),
                [(n.pad, n.pad)] * 2, dimension_numbers=("NHWC", "HWIO", "NHWC"),
                feature_group_count=n.groups) + params[i]["b"]
            if n.relu:
                y = jax.nn.relu(y)
        elif n.op == "maxpool":
            y = jax.lax.reduce_window(outs[n.inputs[0]], -jnp.inf, jax.lax.max,
                                      (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
        elif n.op == "gap":
            y = outs[n.inputs[0]].mean(axis=(1, 2), keepdims=True)
        elif n.op == "dense":
            bsz = outs[n.inputs[0]].shape[0]
            y = outs[n.inputs[0]].reshape(bsz, -1) @ params[i]["w"] + params[i]["b"]
            y = y[:, None, None, :]
            if n.relu:
                y = jax.nn.relu(y)
        elif n.op == "add":
            y = outs[n.inputs[0]] + outs[n.inputs[1]]
            if n.relu:
                y = jax.nn.relu(y)
        elif n.op == "concat":
            y = jnp.concatenate([outs[j] for j in n.inputs], axis=-1)
        elif n.op == "shuffle":
            bsz, h, w, c = outs[n.inputs[0]].shape
            g = n.groups
            y = outs[n.inputs[0]].reshape(bsz, h, w, g, c // g)
            y = y.transpose(0, 1, 2, 4, 3).reshape(bsz, h, w, c)
        else:
            raise ValueError(n.op)
        outs.append(y)
    return outs


def float_forward(nodes, params, x):
    """Float logits [B, n_classes]."""
    return float_forward_all(nodes, params, x)[-1].reshape(x.shape[0], -1)


# ---------------------------------------------------------------------------
# Quantized reference path (numpy; mirror of rust/src/nn)
# ---------------------------------------------------------------------------


def im2col(a_q: np.ndarray, k: int, stride: int, pad: int, zp: int) -> np.ndarray:
    """uint8 [H,W,C] -> [k*k*C, OH*OW]; padding uses the zero-point (real 0)."""
    h, w, c = a_q.shape
    ap = np.full((h + 2 * pad, w + 2 * pad, c), zp, np.uint8)
    ap[pad:pad + h, pad:pad + w] = a_q
    oh = (h + 2 * pad - k) // stride + 1
    ow = (w + 2 * pad - k) // stride + 1
    cols = np.empty((k * k * c, oh * ow), np.uint8)
    idx = 0
    for ky in range(k):
        for kx in range(k):
            patch = ap[ky:ky + oh * stride:stride, kx:kx + ow * stride:stride]
            cols[idx * c:(idx + 1) * c] = patch.reshape(oh * ow, c).T
            idx += 1
    return cols


def np_err_acc(family: str, w: np.ndarray, a: np.ndarray, m: int) -> np.ndarray:
    """sum_k eps(W,A) via the identity matmuls (i64)."""
    w = w.astype(np.int64)
    a = a.astype(np.int64)
    mask = (1 << m) - 1
    if family == "exact" or m == 0:
        return np.zeros((w.shape[0], a.shape[1]), np.int64)
    if family == "perforated":
        return w @ (a & mask)
    if family == "recursive":
        return (w & mask) @ (a & mask)
    if family == "truncated":
        acc = np.zeros((w.shape[0], a.shape[1]), np.int64)
        for i in range(m):
            acc += ((w & ((1 << (m - i)) - 1)) @ ((a >> i) & 1)) << i
        return acc
    raise ValueError(family)


def np_sum_x(family: str, a: np.ndarray, m: int) -> np.ndarray:
    low = a.astype(np.int64) & ((1 << m) - 1)
    if family == "truncated":
        low = (low != 0).astype(np.int64)
    return low.sum(axis=0)


def np_cv_constants(family: str, w: np.ndarray, m: int):
    """Mirror of kernels.ref.cv_constants in numpy (Q.4 integers)."""
    k = w.shape[1]
    w = w.astype(np.int64)
    if family == "perforated":
        num = w.sum(axis=1)
    elif family == "recursive":
        num = (w & ((1 << m) - 1)).sum(axis=1)
    elif family == "truncated":
        num = np.zeros(w.shape[0], np.int64)
        for i in range(m):
            num += (w & ((1 << (m - i)) - 1)).sum(axis=1) << i  # = 2*sum(What)
    else:
        raise ValueError(family)
    den = k * (2 if family == "truncated" else 1)
    c_q4 = (num * 16 + den // 2) // den
    if family == "truncated":
        sh = 1 << (m + 1)
        c0_q4 = (num * 16 + sh // 2) // sh
    else:
        c0_q4 = np.zeros(w.shape[0], np.int64)
    return c_q4, c0_q4


def approx_gemm(family: str, m: int, use_cv: bool,
                w_q: np.ndarray, a_q: np.ndarray,
                zp_w: int, zp_a: int, bias_q: np.ndarray) -> np.ndarray:
    """The full hardware accumulator for one GEMM: [M,N] i64.

    acc = CV(sum AM(W,A)) - zw*sum_a - za*sum_w + K*zw*za + bias
    """
    wi = w_q.astype(np.int64)
    ai = a_q.astype(np.int64)
    kdim = wi.shape[1]
    am_acc = wi @ ai - np_err_acc(family, wi, ai, m)
    if use_cv and family != "exact" and m > 0:
        c_q4, c0_q4 = np_cv_constants(family, w_q, m)
        sum_x = np_sum_x(family, ai, m)
        v_q4 = c_q4[:, None] * sum_x[None, :] + c0_q4[:, None]
        am_acc = am_acc + ((v_q4 + 8) >> 4)
    sum_a = ai.sum(axis=0)
    sum_w = wi.sum(axis=1)
    return (am_acc - zp_w * sum_a[None, :] - zp_a * sum_w[:, None]
            + kdim * zp_w * zp_a + bias_q.astype(np.int64)[:, None])


class QuantModel:
    """Quantized network: nodes + per-node qparams + uint8 weights.

    Produced by `quantize_model`; serialized by export.py; mirrored in rust.
    """

    def __init__(self, name, nodes, shapes, out_q, weights):
        self.name = name
        self.nodes = nodes
        self.shapes = shapes          # per-node (h, w, c)
        self.out_q = out_q            # per-node (scale, zp)
        self.weights = weights        # node_id -> {w_q, b_q, s_w, zp_w}

    def forward(self, img_q: np.ndarray, family="exact", m=0, use_cv=False):
        """One uint8 [H,W,C] image -> float logits [n_classes]."""
        outs: list[np.ndarray] = []
        for i, n in enumerate(self.nodes):
            s_out, zp_out = self.out_q[i]
            if n.op == "input":
                y = img_q
            elif n.op in ("conv", "dense"):
                y = self._mac_layer(i, n, outs, family, m, use_cv)
            elif n.op == "maxpool":
                x = outs[n.inputs[0]]
                h, w, c = x.shape
                y = x[:h // 2 * 2, :w // 2 * 2].reshape(h // 2, 2, w // 2, 2, c)
                y = y.max(axis=(1, 3))
            elif n.op == "gap":
                x = outs[n.inputs[0]].astype(np.int64)
                npix = x.shape[0] * x.shape[1]
                y = ((x.sum(axis=(0, 1)) * 2 + npix) // (2 * npix)).astype(np.uint8)
                y = y.reshape(1, 1, -1)
            elif n.op == "add":
                a, b = outs[n.inputs[0]], outs[n.inputs[1]]
                (s1, z1), (s2, z2) = (self.out_q[j] for j in n.inputs)
                acc = ((a.astype(np.float64) - z1) * s1
                       + (b.astype(np.float64) - z2) * s2)
                y = quant.round_half_away(acc / s_out) + zp_out
                lo = zp_out if n.relu else 0
                y = np.clip(y, lo, 255).astype(np.uint8)
            elif n.op == "concat":
                parts = []
                for j in n.inputs:
                    s_j, z_j = self.out_q[j]
                    q = quant.round_half_away(
                        (outs[j].astype(np.float64) - z_j) * (s_j / s_out)) + zp_out
                    parts.append(np.clip(q, 0, 255).astype(np.uint8))
                y = np.concatenate(parts, axis=-1)
            elif n.op == "shuffle":
                x = outs[n.inputs[0]]
                h, w, c = x.shape
                g = n.groups
                y = x.reshape(h, w, g, c // g).transpose(0, 1, 3, 2).reshape(h, w, c)
            else:
                raise ValueError(n.op)
            outs.append(y)
        s, zp = self.out_q[-1]
        return (outs[-1].reshape(-1).astype(np.float64) - zp) * s

    def _mac_layer(self, i, n, outs, family, m, use_cv):
        wrec = self.weights[i]
        x = outs[n.inputs[0]]
        s_in, zp_in = self.out_q[n.inputs[0]]
        s_out, zp_out = self.out_q[i]
        mult = wrec["s_w"] * s_in / s_out
        zp_w = wrec["zp_w"]
        if n.op == "dense":
            a_cols = x.reshape(-1, 1)  # [nin, 1]
            acc = approx_gemm(family, m, use_cv, wrec["w_q"], a_cols,
                              zp_w, zp_in, wrec["b_q"])
            q = quant.requantize(acc, mult, zp_out).reshape(-1)
            if n.relu:
                q = np.maximum(q, zp_out)
            return q.reshape(1, 1, -1)
        # conv (possibly grouped)
        h, w, cin = x.shape
        oh, ow, cout = self.shapes[i]
        g = n.groups
        y = np.empty((cout, oh * ow), np.uint8)
        cpg_in, cpg_out = cin // g, cout // g
        for gi in range(g):
            xg = x[..., gi * cpg_in:(gi + 1) * cpg_in]
            a_cols = im2col(xg, n.k, n.stride, n.pad, zp_in)
            wq = wrec["w_q"][gi * cpg_out:(gi + 1) * cpg_out]
            bq = wrec["b_q"][gi * cpg_out:(gi + 1) * cpg_out]
            acc = approx_gemm(family, m, use_cv, wq, a_cols, zp_w, zp_in, bq)
            q = quant.requantize(acc, mult, zp_out)
            if n.relu:
                q = np.maximum(q, zp_out)
            y[gi * cpg_out:(gi + 1) * cpg_out] = q
        return y.T.reshape(oh, ow, cout)


def quantize_model(name, nodes, params, calib_imgs, in_shape=(32, 32, 3)) -> QuantModel:
    """Post-training quantization: calibrate activations, quantize weights."""
    shapes = infer_shapes(nodes, in_shape)
    cals = [quant.Calibrator() for _ in nodes]
    outs = float_forward_all(nodes, params, jnp.asarray(calib_imgs))
    for i, y in enumerate(outs):
        cals[i].observe(np.asarray(y))
    out_q = [cals[i].qparams() for i in range(len(nodes))]
    out_q[0] = (quant.INPUT_SCALE, 0)  # inputs live on an exact /255 grid

    weights = {}
    for i, n in enumerate(nodes):
        if n.op not in ("conv", "dense"):
            continue
        w = np.asarray(params[i]["w"], np.float64)
        b = np.asarray(params[i]["b"], np.float64)
        if n.op == "conv":
            # jax layout [k,k,cin/g,cout] -> engine layout [cout, k*k*cin/g]
            # with (ky,kx,cin) minor ordering matching im2col.
            w = w.transpose(3, 0, 1, 2).reshape(w.shape[3], -1)
        else:
            w = w.T  # [nout, nin]
        s_w, zp_w = quant.choose_qparams(w.min(), w.max())
        w_q = quant.quantize(w, s_w, zp_w)
        s_in = out_q[n.inputs[0]][0]
        b_q = quant.quantize_bias(b, s_w, s_in)
        weights[i] = {"w_q": w_q, "b_q": b_q, "s_w": s_w, "zp_w": zp_w}
    return QuantModel(name, nodes, shapes, out_q, weights)
