"""Network IR + the six CNN architectures.

A model is a flat list of nodes (a DAG in topological order). The same IR is
(a) trained in float by model.py / train.py, (b) quantized + exported by
export.py, and (c) executed by the rust `nn` engine — rust/src/nn/graph.rs
mirrors these op semantics exactly.

Ops:
  input                              — quantized image entry point
  conv(cout,k,stride,pad,groups)     — 2D conv, optional fused ReLU
  maxpool(k=2,s=2)                   — 2x2 max pooling
  gap                                — global average pool -> 1x1xC
  dense(nout)                        — fully connected, optional fused ReLU
  add(a,b)                           — residual addition (+ optional ReLU)
  concat(x...)                       — channel concat (inception)
  shuffle(groups)                    — channel shuffle (shufflenet)

The six nets echo the paper's families (GoogLeNet, ResNet44/56, ShuffleNet,
VGG13/16) scaled to this environment's 1-core budget: same motifs, fewer
channels (DESIGN.md §2).
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class Node:
    op: str
    inputs: list[int] = field(default_factory=list)
    # op params (used subset depends on op)
    cout: int = 0
    k: int = 0
    stride: int = 1
    pad: int = 0
    groups: int = 1
    relu: bool = False
    nout: int = 0


class Builder:
    def __init__(self):
        self.nodes: list[Node] = [Node("input")]

    def _add(self, node: Node) -> int:
        self.nodes.append(node)
        return len(self.nodes) - 1

    def conv(self, x, cout, k=3, stride=1, pad=None, groups=1, relu=True):
        pad = (k // 2) if pad is None else pad
        return self._add(Node("conv", [x], cout=cout, k=k, stride=stride,
                               pad=pad, groups=groups, relu=relu))

    def maxpool(self, x):
        return self._add(Node("maxpool", [x], k=2, stride=2))

    def gap(self, x):
        return self._add(Node("gap", [x]))

    def dense(self, x, nout, relu=False):
        return self._add(Node("dense", [x], nout=nout, relu=relu))

    def add(self, a, b, relu=True):
        return self._add(Node("add", [a, b], relu=relu))

    def concat(self, xs):
        return self._add(Node("concat", list(xs)))

    def shuffle(self, x, groups):
        return self._add(Node("shuffle", [x], groups=groups))


def mininet(n_classes: int) -> list[Node]:
    """Small plain CNN (the quickstart net)."""
    b = Builder()
    x = b.conv(0, 16)
    x = b.conv(x, 24)
    x = b.maxpool(x)           # 16x16
    x = b.conv(x, 32)
    x = b.maxpool(x)           # 8x8
    x = b.conv(x, 48)
    x = b.gap(x)
    b.dense(x, n_classes)
    return b.nodes


def vggnet11(n_classes: int) -> list[Node]:
    """VGG-style: stacked 3x3 blocks + maxpool (echoes VGG13)."""
    b = Builder()
    x = 0
    for cout, reps in [(16, 1), (32, 2), (48, 2), (64, 2)]:
        for _ in range(reps):
            x = b.conv(x, cout)
        x = b.maxpool(x)
    x = b.gap(x)               # 2x2 -> gap
    x = b.dense(x, 64, relu=True)
    b.dense(x, n_classes)
    return b.nodes


def _res_block(b: Builder, x: int, cout: int, stride: int) -> int:
    y = b.conv(x, cout, stride=stride)
    y = b.conv(y, cout, relu=False)
    if stride != 1:
        x = b.conv(x, cout, k=1, stride=stride, relu=False)  # projection
    return b.add(x, y, relu=True)


def resnet8(n_classes: int) -> list[Node]:
    """3 residual blocks (echoes ResNet44 family, shallow)."""
    b = Builder()
    x = b.conv(0, 16)
    x = _res_block(b, x, 16, 1)
    x = _res_block(b, x, 32, 2)
    x = _res_block(b, x, 48, 2)
    x = b.gap(x)
    b.dense(x, n_classes)
    return b.nodes


def resnet14(n_classes: int) -> list[Node]:
    """6 residual blocks (echoes ResNet56, deeper variant)."""
    b = Builder()
    x = b.conv(0, 16)
    x = _res_block(b, x, 16, 1)
    x = _res_block(b, x, 16, 1)
    x = _res_block(b, x, 32, 2)
    x = _res_block(b, x, 32, 1)
    x = _res_block(b, x, 48, 2)
    x = _res_block(b, x, 48, 1)
    x = b.gap(x)
    b.dense(x, n_classes)
    return b.nodes


def _inception(b: Builder, x: int, c1: int, c3: int, c5: int, cp: int) -> int:
    br1 = b.conv(x, c1, k=1)
    br3 = b.conv(b.conv(x, c3 // 2, k=1), c3)
    br5 = b.conv(b.conv(b.conv(x, c5 // 2, k=1), c5), c5)  # 5x5 as 2x 3x3
    brp = b.conv(x, cp, k=1)
    return b.concat([br1, br3, br5, brp])


def inceptionnet(n_classes: int) -> list[Node]:
    """Parallel-branch concat modules (echoes GoogLeNet)."""
    b = Builder()
    x = b.conv(0, 16)
    x = b.maxpool(x)                       # 16x16
    x = _inception(b, x, 8, 16, 8, 8)      # -> 40ch
    x = b.maxpool(x)                       # 8x8
    x = _inception(b, x, 16, 24, 12, 12)   # -> 64ch
    x = _inception(b, x, 16, 32, 16, 16)   # -> 80ch
    x = b.gap(x)
    b.dense(x, n_classes)
    return b.nodes


def _shuffle_unit(b: Builder, x: int, cout: int, groups: int, stride: int) -> int:
    y = b.conv(x, cout, k=1, groups=groups)
    y = b.shuffle(y, groups)
    y = b.conv(y, cout, k=3, stride=stride, groups=cout, relu=False)  # depthwise
    y = b.conv(y, cout, k=1, groups=groups, relu=False)
    if stride == 1:
        return b.add(x, y, relu=True)
    x = b.conv(x, cout, k=1, stride=stride, relu=False)  # projection shortcut
    return b.add(x, y, relu=True)


def shufflenet(n_classes: int) -> list[Node]:
    """Grouped 1x1 conv + channel shuffle + depthwise 3x3 (echoes ShuffleNet)."""
    b = Builder()
    x = b.conv(0, 16)
    x = b.maxpool(x)                        # 16x16
    x = _shuffle_unit(b, x, 32, 2, 2)       # 8x8
    x = _shuffle_unit(b, x, 32, 2, 1)
    x = _shuffle_unit(b, x, 64, 4, 2)       # 4x4
    x = _shuffle_unit(b, x, 64, 4, 1)
    x = b.gap(x)
    b.dense(x, n_classes)
    return b.nodes


NETS = {
    "mininet": mininet,
    "vggnet11": vggnet11,
    "resnet8": resnet8,
    "resnet14": resnet14,
    "inceptionnet": inceptionnet,
    "shufflenet": shufflenet,
}
