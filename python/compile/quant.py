"""Asymmetric uint8 quantization (TPU-style), shared python/rust semantics.

Per-tensor affine quantization: real = scale * (q - zero_point), q in [0,255].
Weights and activations are both uint8 (the paper's multipliers are unsigned
8x8); accumulation is i32. The integer GEMM with zero points expands as

  sum_k (W-z_w)(A-z_a) = sum_k W*A - z_w*sum_a - z_a*sum_w + K*z_w*z_a

and only the raw uint8 product sum_k W*A goes through the approximate
multiplier array; the row/column sums are exact side accumulators the
hardware keeps anyway (they share the sumX datapath structure).

Requantization to the next layer's uint8 domain uses a single f32 multiplier
M = s_w*s_a/s_out with round-half-away-from-zero — both the python reference
and the rust engine implement exactly this, so quantized forwards match
bit-for-bit (asserted by golden-vector integration tests).
"""

from __future__ import annotations

import numpy as np

# Input images live on an exact 1/255 grid; stored as f32 in the .cvd binaries.
INPUT_SCALE = float(np.float32(1.0 / 255.0))


def choose_qparams(x_min: float, x_max: float) -> tuple[float, int]:
    """Scale/zero-point covering [x_min, x_max] with 0 exactly representable."""
    x_min = min(0.0, float(x_min))
    x_max = max(0.0, float(x_max))
    if x_max == x_min:
        return 1.0, 0
    # Round the scale to f32 BEFORE deriving anything from it: the .cvm/.cvd
    # binaries store f32, and the rust engine must compute bit-identical
    # requantization multipliers.
    scale = float(np.float32((x_max - x_min) / 255.0))
    zp = int(round(-x_min / scale))
    return scale, int(np.clip(zp, 0, 255))


def quantize(x: np.ndarray, scale: float, zp: int) -> np.ndarray:
    """float -> uint8."""
    q = np.round(x / scale) + zp
    return np.clip(q, 0, 255).astype(np.uint8)


def dequantize(q: np.ndarray, scale: float, zp: int) -> np.ndarray:
    return (q.astype(np.float32) - zp) * scale


def round_half_away(x: np.ndarray) -> np.ndarray:
    """Deterministic round-half-away-from-zero (np.round is half-to-even)."""
    return np.sign(x) * np.floor(np.abs(x) + 0.5)


def requantize(acc: np.ndarray, mult: float, out_zp: int) -> np.ndarray:
    """i32 accumulator -> uint8 output: clamp(round(acc*mult) + zp)."""
    q = round_half_away(acc.astype(np.float64) * np.float64(mult)) + out_zp
    return np.clip(q, 0, 255).astype(np.uint8)


def quantize_bias(b: np.ndarray, s_w: float, s_a: float) -> np.ndarray:
    """Bias folds into the i32 accumulator domain: b_q = round(b/(s_w*s_a))."""
    return round_half_away(b.astype(np.float64) / (s_w * s_a)).astype(np.int64).astype(np.int32)


class Calibrator:
    """Tracks min/max of a float tensor stream for post-training calibration.

    Uses percentile clipping (99.95%) to shave outliers — standard PTQ
    practice; keeps the uint8 grid dense where activations actually live.
    """

    def __init__(self, percentile: float = 99.95):
        self.percentile = percentile
        self.mins: list[float] = []
        self.maxs: list[float] = []

    def observe(self, x: np.ndarray) -> None:
        lo = 100.0 - self.percentile
        self.mins.append(float(np.percentile(x, lo)))
        self.maxs.append(float(np.percentile(x, self.percentile)))

    def qparams(self) -> tuple[float, int]:
        return choose_qparams(np.mean(self.mins), np.mean(self.maxs))
