//! End-to-end driver (the EXPERIMENTS.md headline run): serve the synth10
//! test set through the batching coordinator for the exact design and every
//! approximate family (with and without the control variate), reporting
//! accuracy, latency, throughput and modeled power — the paper's headline
//! claim ("same performance, ~45% power reduction, <1% accuracy loss").
//!
//! Run: `cargo run --release --example e2e_inference [-- n_images [engine]]`
//! engine ∈ {native, lut, pjrt, pjrt-pallas} (default native)

use std::sync::Arc;

use anyhow::Result;
use cvapprox::approx::Family;
use cvapprox::coordinator::{InferenceService, ServiceConfig};
use cvapprox::datasets::Dataset;
use cvapprox::nn::{loader, Engine};
use cvapprox::runtime::{TileGemm, Variant};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(300);
    let engine_kind = args.get(1).map(|s| s.as_str()).unwrap_or("native").to_string();
    let art = cvapprox::artifacts_dir();
    let ds = Dataset::load(&art.join("data/synth10_test.cvd"))?;
    let n = n.min(ds.n);
    let net = "resnet8";
    let n_array = 64;

    // The paper's representative design points (Tables 2-4 midpoints).
    let mut points: Vec<(Family, u32, bool)> = vec![(Family::Exact, 0, false)];
    for family in Family::APPROX {
        let m = family.paper_levels()[1]; // mid approximation
        points.push((family, m, false));
        points.push((family, m, true));
    }

    println!(
        "E2E: {net}/synth10, {n} requests through the batching coordinator \
         (engine={engine_kind}, array {n_array}x{n_array})\n"
    );
    println!(
        "{:<26} {:>8} {:>10} {:>11} {:>11} {:>9}",
        "design point", "acc", "img/s", "mean ms", "~p95 ms", "energy"
    );

    let pjrt: Option<Arc<TileGemm>> = if engine_kind.starts_with("pjrt") {
        let rt = Arc::new(TileGemm::new(&art)?);
        eprintln!("PJRT platform: {}", rt.platform());
        Some(rt)
    } else {
        None
    };

    let mut exact_acc = None;
    for (family, m, use_cv) in points {
        let model = loader::load_model(&art.join(format!("models/{net}_synth10.cvm")))?;
        let mut engine = Engine::new(model);
        match engine_kind.as_str() {
            "lut" => engine.prepare_lut(family, m),
            "pjrt" => engine.attach_pjrt(pjrt.clone().unwrap(), Variant::Fast),
            "pjrt-pallas" => engine.attach_pjrt(pjrt.clone().unwrap(), Variant::Pallas),
            _ => {}
        }
        let cfg = ServiceConfig {
            family,
            m,
            use_cv,
            n_array,
            batch_size: 8,
            ..Default::default()
        };
        let svc = InferenceService::start(engine, cfg)?;
        let pending = (0..n)
            .map(|i| svc.submit(ds.image(i)))
            .collect::<Result<Vec<_>>>()?;
        let mut correct = 0usize;
        for (i, p) in pending.into_iter().enumerate() {
            correct += (p.wait()?.top1 == ds.label(i)) as usize;
        }
        let snap = svc.shutdown();
        let acc = correct as f64 / n as f64;
        if family == Family::Exact {
            exact_acc = Some(acc);
        }
        let label = if family == Family::Exact {
            "exact".to_string()
        } else {
            format!("{} m={m} {}", family.name(), if use_cv { "+V (ours)" } else { "raw" })
        };
        println!(
            "{:<26} {:>7.1}% {:>10.1} {:>11.2} {:>11.2} {:>8.3}x",
            label,
            100.0 * acc,
            snap.throughput_rps,
            snap.mean_latency.as_secs_f64() * 1e3,
            snap.p95_latency.as_secs_f64() * 1e3,
            snap.energy_vs_exact,
        );
    }
    if let Some(e) = exact_acc {
        println!(
            "\n(accuracy loss vs exact = {:.1}% minus each row; energy is modeled \
             power of the {n_array}x{n_array} array, 1.0 = exact design)",
            100.0 * e
        );
    }
    Ok(())
}
