//! Hardware-faithful power measurement: run real inference traffic through
//! the cycle-level systolic array and compare **measured switching
//! activity** (bit toggles) against the static cost model — the rust
//! analogue of the paper's Questasim back-annotated power simulation
//! (10k inference cycles).
//!
//! Run: `cargo run --release --example hw_power_sim [-- n_images]`

use anyhow::Result;
use cvapprox::approx::Family;
use cvapprox::datasets::Dataset;
use cvapprox::hw::array_cost;
use cvapprox::nn::{loader, Engine, ForwardOpts};

fn main() -> Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(3);
    let art = cvapprox::artifacts_dir();
    let ds = Dataset::load(&art.join("data/synth10_test.cvd"))?;
    let n_array = 64usize;

    println!(
        "Cycle-level systolic simulation, shufflenet/synth10, {n} images, \
         {n_array}x{n_array} array\n"
    );
    println!(
        "{:<18} {:>14} {:>12} {:>14} {:>12}",
        "design", "cycles", "toggles/cyc", "vs exact", "model power"
    );

    let mut exact_activity = None;
    let points = [
        (Family::Exact, 0u32),
        (Family::Perforated, 3),
        (Family::Truncated, 7),
        (Family::Recursive, 4),
    ];
    for (family, m) in points {
        let model = loader::load_model(&art.join("models/shufflenet_synth10.cvm"))?;
        let mut engine = Engine::new(model);
        engine.prepare_systolic(family, m, n_array);
        let opts = ForwardOpts::approx(family, m, true);
        let mut total = cvapprox::systolic::ToggleStats::default();
        for i in 0..n {
            let (_logits, stats) = engine.forward_systolic(&ds.image(i), &opts)?;
            total.merge(&stats);
        }
        let act = total.activity();
        if family == Family::Exact {
            exact_activity = Some(act);
        }
        let rel = act / exact_activity.unwrap();
        println!(
            "{:<18} {:>14} {:>12.2} {:>13.3}x {:>11.3}x",
            format!("{} m={m}", family.name()),
            total.cycles,
            act,
            rel,
            array_cost(family, m, n_array as u32).power_norm,
        );
    }
    println!(
        "\n'vs exact' is measured datapath switching activity (register bit\n\
         toggles per MAC cycle) from the bit-exact simulator; 'model power' is\n\
         the calibrated static cost model. The measured activity ordering\n\
         independently confirms the model's family ranking."
    );
    Ok(())
}
