//! Quickstart: load a quantized model, classify a few images with an exact
//! array, then with a highly-approximate multiplier — with and without the
//! paper's control-variate correction.
//!
//! Run: `cargo run --release --example quickstart`
//! (requires `make artifacts` first)

use anyhow::Result;
use cvapprox::approx::Family;
use cvapprox::coordinator::service::argmax;
use cvapprox::datasets::Dataset;
use cvapprox::nn::{loader, Engine, ForwardOpts};

fn main() -> Result<()> {
    let art = cvapprox::artifacts_dir();
    let model = loader::load_model(&art.join("models/mininet_synth10.cvm"))?;
    println!(
        "loaded {}: {} nodes, {} params, {} MACs/inference",
        model.name,
        model.nodes.len(),
        model.params(),
        model.macs()
    );
    let ds = Dataset::load(&art.join("data/synth10_test.cvd"))?;
    let engine = Engine::new(model);

    // Three design points: exact, aggressive approximation without V, and
    // the same approximation with the control variate (the paper's method).
    let configs = [
        ("exact multiplier      ", ForwardOpts::exact()),
        ("perforated m=3 (raw)  ", ForwardOpts::approx(Family::Perforated, 3, false)),
        ("perforated m=3 + V    ", ForwardOpts::approx(Family::Perforated, 3, true)),
    ];
    let n = 100;
    println!("\nclassifying {n} test images:");
    for (label, opts) in &configs {
        let mut correct = 0;
        for i in 0..n {
            let logits = engine.forward(&ds.image(i), opts)?;
            correct += (argmax(&logits) == ds.label(i)) as usize;
        }
        println!("  {label} accuracy: {:.1}%", 100.0 * correct as f64 / n as f64);
    }
    println!(
        "\nThe control variate recovers the accuracy the approximation destroyed,\n\
         while the hardware still saves ~{:.0}% power (see `cvapprox figure7`).",
        100.0 * (1.0 - cvapprox::hw::array_cost(Family::Perforated, 3, 64).power_norm)
    );
    Ok(())
}
