//! Design-space exploration: joint accuracy/power sweep across every
//! (family, m) point — a compact Fig.-10-style Pareto walk plus the
//! hardware figures, for one network.
//!
//! Run: `cargo run --release --example design_space [-- net [n_images]]`

use anyhow::Result;
use cvapprox::approx::Family;
use cvapprox::hw::array_cost;
use cvapprox::report::accuracy::{pareto_front, pareto_points};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let net = args.first().map(|s| s.as_str()).unwrap_or("resnet8").to_string();
    let n: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(150);
    let art = cvapprox::artifacts_dir();

    println!("Design space for {net} on synth100 (N=64 array, {n} test images)\n");
    println!(
        "{:<13} {:>2} {:>5} {:>8} {:>9} {:>9}  {}",
        "family", "m", "V?", "power", "area", "loss%", "pareto-optimal?"
    );
    let points = pareto_points(&art, &net, "synth100", n, 64, 1)?;
    let front = pareto_front(&points);
    let mut sorted = points.clone();
    sorted.sort_by(|a, b| a.power_norm.partial_cmp(&b.power_norm).unwrap());
    for p in &sorted {
        let area = array_cost(p.family, p.m, 64).area_norm;
        let on_front =
            front.iter().any(|f| f.family == p.family && f.m == p.m && f.use_cv == p.use_cv);
        println!(
            "{:<13} {:>2} {:>5} {:>8.3} {:>9.3} {:>+9.2}  {}",
            p.family.name(),
            p.m,
            if p.use_cv { "yes" } else { "no" },
            p.power_norm,
            area,
            p.acc_loss_pct,
            if on_front { "*" } else { "" }
        );
    }
    println!(
        "\n{} of {} points are Pareto-optimal; every front point at aggressive \
         approximation uses V — the paper's Fig. 10 observation.",
        front.len(),
        points.len()
    );
    // The paper's qualitative guidance (§5.2): recursive for tight accuracy
    // budgets, perforated for relaxed ones.
    let tightest = front.first();
    if let Some(p) = tightest {
        println!(
            "lowest-loss front point: {} m={} (V={})",
            p.family.name(),
            p.m,
            p.use_cv
        );
    }
    Ok(())
}
