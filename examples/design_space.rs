//! Design-space exploration: joint accuracy/power sweep across every
//! (family, m) point — a compact Fig.-10-style Pareto walk plus the
//! hardware figures, for one network. Optionally overlays a per-layer
//! heterogeneous policy (e.g. the artifact `cvapprox layerwise --json`
//! emits) to show where mixed-m assignments land relative to the uniform
//! front.
//!
//! Run: `cargo run --release --example design_space [-- net [n_images] [--policy FILE]]`

use anyhow::Result;
use cvapprox::approx::Family;
use cvapprox::datasets::Dataset;
use cvapprox::hw::array_cost;
use cvapprox::nn::{loader, Engine, ForwardOpts, LayerPolicy};
use cvapprox::report::accuracy::{evaluate, pareto_front, pareto_points};

fn main() -> Result<()> {
    let mut positional: Vec<String> = Vec::new();
    let mut policy_path: Option<String> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        if a == "--policy" {
            policy_path = Some(
                args.next()
                    .ok_or_else(|| anyhow::anyhow!("--policy needs a FILE argument"))?,
            );
        } else {
            positional.push(a);
        }
    }
    let net = positional.first().map(|s| s.as_str()).unwrap_or("resnet8").to_string();
    let n: usize = positional.get(1).and_then(|s| s.parse().ok()).unwrap_or(150);
    let art = cvapprox::artifacts_dir();

    println!("Design space for {net} on synth100 (N=64 array, {n} test images)\n");
    println!(
        "{:<13} {:>2} {:>5} {:>8} {:>9} {:>9}  {}",
        "family", "m", "V?", "power", "area", "loss%", "pareto-optimal?"
    );
    let points = pareto_points(&art, &net, "synth100", n, 64, 1)?;
    let front = pareto_front(&points);
    let mut sorted = points.clone();
    sorted.sort_by(|a, b| a.power_norm.partial_cmp(&b.power_norm).unwrap());
    for p in &sorted {
        let area = array_cost(p.family, p.m, 64).area_norm;
        let on_front =
            front.iter().any(|f| f.family == p.family && f.m == p.m && f.use_cv == p.use_cv);
        println!(
            "{:<13} {:>2} {:>5} {:>8.3} {:>9.3} {:>+9.2}  {}",
            p.family.name(),
            p.m,
            if p.use_cv { "yes" } else { "no" },
            p.power_norm,
            area,
            p.acc_loss_pct,
            if on_front { "*" } else { "" }
        );
    }
    println!(
        "\n{} of {} points are Pareto-optimal; every front point at aggressive \
         approximation uses V — the paper's Fig. 10 observation.",
        front.len(),
        points.len()
    );
    // The paper's qualitative guidance (§5.2): recursive for tight accuracy
    // budgets, perforated for relaxed ones.
    let tightest = front.first();
    if let Some(p) = tightest {
        println!(
            "lowest-loss front point: {} m={} (V={})",
            p.family.name(),
            p.m,
            p.use_cv
        );
    }

    // ---- per-layer policy overlay (ALWANN-style mixed-m) -----------------
    if let Some(path) = policy_path {
        let policy = LayerPolicy::load(std::path::Path::new(&path))?;
        let model =
            loader::load_model(&art.join(format!("models/{net}_synth100.cvm")))?;
        policy.validate_for(&model)?;
        let ds = Dataset::load(&art.join("data/synth100_test.cvd"))?;
        let engine = Engine::new(model);
        let exact = evaluate(&engine, &ds, &ForwardOpts::exact(), n, 1)?;
        let policy = std::sync::Arc::new(policy);
        let acc =
            evaluate(&engine, &ds, &ForwardOpts::with_policy(policy.clone()), n, 1)?;
        let loss = 100.0 * (exact - acc);
        let power = policy.power_norm(&engine.model, 64);
        println!(
            "\npolicy {path}: {}\n  loss {loss:+.2}%  MAC-weighted power {power:.3}x",
            policy.describe()
        );
        let beaten = points
            .iter()
            .filter(|u| u.acc_loss_pct <= loss + 1e-9 && power < u.power_norm)
            .count();
        let at_or_below = points
            .iter()
            .filter(|u| u.acc_loss_pct <= loss + 1e-9)
            .count();
        println!(
            "  beats {beaten}/{at_or_below} uniform points at equal-or-lower loss \
             on power"
        );
    }
    Ok(())
}
